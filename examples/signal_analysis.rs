//! Signal analysis: dumps per-iteration traces of the quantities behind the
//! paper's Figures 2 and 5 — the oracle-optimal speculation length (how
//! volatile the per-step optimum really is) alongside the DSDE adapter's
//! signals (μ_KLD, WVIR, SF, predicted SL) — as CSV for plotting.
//!
//! ```bash
//! cargo run --release --offline --example signal_analysis -- \
//!     [--dataset cnndm] [--steps 200] [--out signals.csv]
//! ```

use std::io::Write;

use dsde::model::sim_lm::{SimModel, SimPairKind};
use dsde::model::traits::{SeqInput, SpecModel};
use dsde::sim::regime::DatasetProfile;
use dsde::spec::adapter::{DsdeAdapter, DsdeConfig, SlPolicy};
use dsde::spec::history::SeqSignals;
use dsde::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 200);
    let dataset = args.str_or("dataset", "cnndm");
    let out_path = args.str_or("out", "signals.csv");
    let profile = DatasetProfile::by_name(&dataset).expect("unknown dataset");

    let mut model = SimModel::new(SimPairKind::LlamaLike, profile, 5);
    let adapter = DsdeAdapter::new(DsdeConfig::default());
    let mut signals = SeqSignals::default();
    let tokens = vec![65u32; 32];

    let mut csv = String::from(
        "step,drafted,accepted,oracle_opt_sl,mean_kld,wvir,scale_factor,penalty,predicted_sl\n",
    );
    let mut predicted = adapter.propose(&signals);
    for step in 0..steps {
        // always draft the max so we can observe the oracle optimum
        let k = model.spec_k();
        let seqs = [SeqInput {
            id: 0,
            tokens: &tokens,
            temperature: 0.0,
        }];
        let out = model.spec_round(&seqs, &[k], &|_, _, _, _| false)?;
        // oracle optimal SL for this step: exactly the accepted run length
        // (drafting more wastes draft compute; less forfeits accepted tokens)
        let oracle = out.accepted[0].max(1);
        // feed the adapter what it would have seen had it drafted `predicted`
        let seen = predicted.min(out.drafted[0]).max(1);
        let klds = &out.klds[0][..seen];
        let ents = &out.entropies[0][..seen];
        let acc_seen = out.accepted[0].min(seen);
        if signals.calibrated_sl_max.is_none() {
            signals.record_calibration(klds, acc_seen);
        }
        signals.record_step(klds, ents, seen, acc_seen);
        if signals.calibrated_sl_max.is_none() && signals.steps >= 4 {
            signals.calibrated_sl_max = Some(adapter.calibrated_sl_max(&signals));
        }
        let sf = adapter.scale_factor(&signals);
        let wvir = signals.wvir();
        predicted = adapter.propose(&signals);
        csv.push_str(&format!(
            "{step},{},{},{oracle},{:.4},{:.4},{:.4},{:.4},{predicted}\n",
            out.drafted[0],
            out.accepted[0],
            signals.last_step_mean_kld,
            wvir,
            sf,
            sf * wvir,
        ));
    }
    std::fs::File::create(&out_path)?.write_all(csv.as_bytes())?;
    println!("wrote {steps} steps of signal traces to {out_path}");

    // quick textual summary (Fig. 2's point: the optimum is volatile)
    let lines: Vec<&str> = csv.lines().skip(1).collect();
    let oracles: Vec<f64> = lines
        .iter()
        .map(|l| l.split(',').nth(3).unwrap().parse().unwrap())
        .collect();
    let preds: Vec<f64> = lines
        .iter()
        .map(|l| l.split(',').nth(8).unwrap().parse().unwrap())
        .collect();
    let flips = oracles.windows(2).filter(|w| w[0] != w[1]).count();
    println!(
        "oracle-opt SL: mean {:.2}, changes between consecutive steps {}/{} \
         (the Fig. 2 volatility)",
        dsde::util::stats::mean(&oracles),
        flips,
        oracles.len() - 1
    );
    println!(
        "DSDE predicted SL: mean {:.2} (tracks the *regional* level, not the \
         per-step noise)",
        dsde::util::stats::mean(&preds)
    );
    Ok(())
}
