//! End-to-end driver over the REAL model path: loads the AOT-compiled
//! tiny transformer pair (trained + distilled at `make artifacts`), serves a
//! mixed batched workload through the full engine — draft worker, ragged
//! Pallas-kernel verify, exact rejection sampling, DSDE adapter, SL-cap,
//! paged KV — and reports the paper's metrics.  This is the run recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_serving -- \
//!     [--requests 64] [--batch 8] [--policy dsde] [--temperature 0.0]
//! ```

use std::time::Instant;

use dsde::config::{CapMode, EngineConfig, SlPolicyKind};
use dsde::engine::engine::Engine;
use dsde::model::pjrt_lm::PjrtModel;
use dsde::model::traits::SpecModel;
use dsde::runtime::artifacts::DraftKind;
use dsde::util::cli::Args;
use dsde::util::stats::percentile;
use dsde::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    dsde::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 64);
    let batch = args.usize_or("batch", 8);
    let temp = args.f64_or("temperature", 0.0);
    let policy = SlPolicyKind::parse(&args.str_or("policy", "dsde")).unwrap();
    let artifacts = args.str_or("artifacts", "artifacts");

    println!("== DSDE end-to-end serving (real PJRT path) ==");
    let t0 = Instant::now();
    let mut model = PjrtModel::new(&artifacts, DraftKind::Good, 7)?;
    model.warmup(batch)?;
    println!("model pair loaded + compiled in {:.1}s", t0.elapsed().as_secs_f64());

    let cfg = EngineConfig {
        max_batch: batch,
        max_len: model.max_len(),
        spec_k: 8,
        speculative: !args.flag("ar"),
        policy,
        cap_mode: CapMode::Mean,
        temperature: temp,
        kv_blocks: 4096,
        seed: 7,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg, Box::new(model));

    // mixed workload: the Table-1 heterogeneity axis (code vs dialogue vs
    // math vs prose), constrained to the tiny model's 160-token context
    let mix = ["humaneval", "sharegpt", "gsm8k", "cnndm"];
    let mut submitted = 0;
    for (w, name) in mix.iter().enumerate() {
        let mut gen = WorkloadGen::new(Dataset::by_name(name).unwrap(), 7 + w as u64)
            .with_temperature(temp)
            .with_limits(48, 72);
        for mut req in gen.batch(n_requests / mix.len()) {
            req.id = submitted as u64;
            submitted += 1;
            engine.submit(req);
        }
    }

    println!("{submitted} requests submitted (mixed {mix:?}); serving...");
    let t1 = Instant::now();
    let done = engine.run_to_completion();
    let wall = t1.elapsed().as_secs_f64();

    let lats: Vec<f64> = done.iter().map(|r| r.latency()).collect();
    let total_tokens: usize = done.iter().map(|r| r.output.len()).sum();
    println!("\n== results ==");
    println!("requests completed : {}", done.len());
    println!("wall time          : {wall:.1} s");
    println!("output tokens      : {total_tokens}");
    println!("throughput         : {:.1} tok/s", total_tokens as f64 / wall);
    println!("mean latency       : {:.2} s", dsde::util::stats::mean(&lats));
    println!("p50 / p99 latency  : {:.2} / {:.2} s", percentile(&lats, 0.5), percentile(&lats, 0.99));
    println!("block efficiency   : {:.2} tokens/verify", engine.metrics.block_efficiency());
    println!("acceptance rate    : {:.3}", engine.metrics.acceptance_rate());
    println!("verify rounds      : {}", engine.metrics.verify_rounds);
    println!("straggler bubble   : {} slots", engine.metrics.straggler_bubble);
    println!("policy             : {}", engine.policy_name());

    // show a couple of real generations (byte-LM text)
    println!("\n== sample generations ==");
    for r in done.iter().take(3) {
        println!("[req {}] {:?}", r.id, r.output_text());
    }
    println!("\nmetrics json: {}", engine.metrics.to_json());
    Ok(())
}
