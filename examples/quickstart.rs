//! Quickstart: run the DSDE engine over the calibrated simulator — no
//! artifacts needed, finishes in well under a second.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dsde::config::{CapMode, EngineConfig, SlPolicyKind};
use dsde::engine::engine::Engine;
use dsde::model::sim_lm::{SimModel, SimPairKind};
use dsde::sim::regime::DatasetProfile;
use dsde::spec::adapter::DsdeConfig;
use dsde::workload::{Dataset, WorkloadGen};

fn main() {
    // 1. engine configuration: the paper's adapter + mean SL-cap
    let cfg = EngineConfig {
        max_batch: 8,
        max_len: 4096,
        speculative: true,
        policy: SlPolicyKind::Dsde(DsdeConfig::default()),
        cap_mode: CapMode::Mean,
        seed: 42,
        ..Default::default()
    };

    // 2. a model pair: LLaMA-70B/1B-like acceptance dynamics on CNN/DM
    let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 42);

    // 3. submit a workload batch and run to completion
    let mut engine = Engine::new(cfg, Box::new(model));
    let mut gen = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
    for req in gen.batch(16) {
        engine.submit(req);
    }
    let done = engine.run_to_completion();

    // 4. report
    println!("DSDE quickstart — {} requests completed", done.len());
    println!("  policy            : {}", engine.policy_name());
    println!("  model             : {}", engine.model_name());
    println!("  mean latency      : {:.2} s (virtual)", engine.metrics.mean_latency());
    println!("  p99 latency       : {:.2} s", engine.metrics.p99_latency());
    println!("  block efficiency  : {:.2} tokens/verify", engine.metrics.block_efficiency());
    println!("  acceptance rate   : {:.3}", engine.metrics.acceptance_rate());
    println!("  throughput        : {:.1} tok/s", engine.metrics.throughput());
    println!("  straggler bubble  : {} idle draft slots", engine.metrics.straggler_bubble);

    // compare against the autoregressive baseline
    let cfg_ar = EngineConfig {
        speculative: false,
        max_len: 4096,
        max_batch: 8,
        seed: 42,
        ..Default::default()
    };
    let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 42);
    let mut ar = Engine::new(cfg_ar, Box::new(model));
    let mut gen = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
    for req in gen.batch(16) {
        ar.submit(req);
    }
    ar.run_to_completion();
    println!(
        "  speedup vs AR     : {:.2}x ({:.2}s -> {:.2}s)",
        ar.metrics.mean_latency() / engine.metrics.mean_latency(),
        ar.metrics.mean_latency(),
        engine.metrics.mean_latency()
    );
}
