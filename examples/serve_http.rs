//! HTTP serving demo: brings up the completions server (simulated pair by
//! default, `--pjrt` for the real artifacts), fires a closed-loop client
//! load at it, then streams a few completions to measure client-observed
//! time-to-first-token, and prints client-side + server-side metrics
//! (latency AND TTFT).  With `--replicas N` the server runs N engine
//! replicas behind the router.
//!
//! ```bash
//! cargo run --release --offline --example serve_http -- [--pjrt] \
//!     [--requests 24] [--concurrency 6] [--replicas 2] \
//!     [--route least-loaded|kv-aware] [--no-steal] \
//!     [--frontend threaded|event-loop]
//! ```

use dsde::config::{CapMode, EngineConfig, FrontendKind, RoutePolicy, SlPolicyKind};
use dsde::engine::engine::Engine;
use dsde::model::pjrt_lm::PjrtModel;
use dsde::model::sim_lm::{SimModel, SimPairKind};
use dsde::model::traits::SpecModel;
use dsde::runtime::artifacts::DraftKind;
use dsde::server::router::EngineRouter;
use dsde::server::{client, http};
use dsde::sim::regime::DatasetProfile;
use dsde::spec::adapter::DsdeConfig;
use dsde::util::cli::Args;
use dsde::util::stats::{mean, percentile};

fn main() -> anyhow::Result<()> {
    dsde::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("requests", 24);
    let concurrency = args.usize_or("concurrency", 6);
    let replicas = args.usize_clamped_or("replicas", 1, 1, 64);
    let route = RoutePolicy::parse(&args.str_or("route", "round-robin"))
        .ok_or_else(|| anyhow::anyhow!("unknown route policy"))?;
    let steal = !args.flag("no-steal");
    let frontend = FrontendKind::parse(&args.str_or("frontend", "threaded"))
        .ok_or_else(|| anyhow::anyhow!("unknown front-end (threaded | event-loop)"))?;
    let use_pjrt = args.flag("pjrt");

    let engines: Vec<Engine> = (0..replicas)
        .map(|i| -> anyhow::Result<Engine> {
            let seed = 3 + i as u64;
            let mut cfg = EngineConfig {
                max_batch: concurrency.max(2),
                max_len: 4096,
                policy: SlPolicyKind::Dsde(DsdeConfig::default()),
                cap_mode: CapMode::Mean,
                seed,
                ..Default::default()
            };
            let model: Box<dyn SpecModel> = if use_pjrt {
                let m = PjrtModel::new(
                    args.str_or("artifacts", "artifacts"),
                    DraftKind::Good,
                    seed,
                )?;
                cfg.max_len = m.max_len();
                cfg.spec_k = 8;
                Box::new(m)
            } else {
                Box::new(SimModel::new(
                    SimPairKind::LlamaLike,
                    DatasetProfile::sharegpt(),
                    seed,
                ))
            };
            Ok(Engine::new(cfg, model))
        })
        .collect::<anyhow::Result<_>>()?;

    let router = EngineRouter::with_options(engines, route, steal);
    let opts = http::ServeOptions {
        frontend,
        ..Default::default()
    };
    let handle = http::serve_router_with(router, "127.0.0.1:0", opts)?;
    let addr = handle.addr.to_string();
    println!(
        "server up at http://{addr} (pjrt={use_pjrt}, replicas={replicas}, \
         route={}, steal={}, frontend={})",
        route.name(),
        handle.router().stealing_enabled(),
        frontend.name()
    );

    // closed-loop load
    let prompts: Vec<String> = (0..n)
        .map(|i| match i % 3 {
            0 => format!("def compute_{i}(x):"),
            1 => format!("User: question {i}?\nAgent: "),
            _ => format!("Q: A box holds {i} items. A: "),
        })
        .collect();
    let max_tokens = if use_pjrt { 48 } else { 96 };
    let t0 = std::time::Instant::now();
    let results = client::closed_loop(&addr, prompts, max_tokens, 0.0, concurrency);
    let wall = t0.elapsed().as_secs_f64();

    let ok = results.iter().filter(|r| r.status == 200).count();
    let walls: Vec<f64> = results.iter().map(|r| r.wall_s).collect();
    println!("\n== client view (blocking) ==");
    println!("completed     : {ok}/{n}");
    println!("wall time     : {wall:.2} s  ({:.1} req/s)", ok as f64 / wall);
    println!("mean / p99    : {:.3} / {:.3} s", mean(&walls), percentile(&walls, 0.99));

    // streaming: consume chunked deltas and measure TTFT at the client
    let n_stream = concurrency.clamp(2, 8);
    let mut ttfts = Vec::new();
    let mut swalls = Vec::new();
    let mut delta_counts = Vec::new();
    for i in 0..n_stream {
        let r = client::complete_streaming(
            &addr,
            &format!("stream probe {i}"),
            max_tokens,
            0.0,
        )?;
        ttfts.push(r.ttft_s);
        swalls.push(r.wall_s);
        delta_counts.push(r.deltas.len() as f64);
    }
    println!("\n== client view (streaming, {n_stream} requests) ==");
    println!("ttft mean/p99 : {:.3} / {:.3} s", mean(&ttfts), percentile(&ttfts, 0.99));
    println!("e2e  mean/p99 : {:.3} / {:.3} s", mean(&swalls), percentile(&swalls, 0.99));
    println!("deltas/request: {:.1}", mean(&delta_counts));

    let m = client::metrics(&addr)?;
    println!("\n== server view (aggregated over {replicas} replica(s)) ==");
    println!("{m}");
    let get = |k: &str| m.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    println!(
        "\nserver latency mean {:.3}s  ttft mean {:.4}s (p99 {:.4}s)  itl mean {:.4}s",
        get("mean_latency"),
        get("mean_ttft"),
        get("p99_ttft"),
        get("mean_itl"),
    );
    println!(
        "route={}  work stealing {} ({} request(s) migrated)",
        handle.router().policy().name(),
        if handle.router().stealing_enabled() { "on" } else { "off" },
        handle.router().steals(),
    );
    let fs = handle.frontend_stats();
    println!(
        "frontend={}  connections accepted={} rejected={} open={}",
        fs.kind().name(),
        fs.accepted(),
        fs.rejected(),
        fs.open(),
    );
    handle.shutdown();
    Ok(())
}
