"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

The hypothesis sweeps cover the shape/dtype/length space; the deterministic
tests pin the edge cases the engine actually produces (len=1 prefix, full
window, ragged batches).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kld_stats import kld_signal as pal_kld
from compile.kernels.ragged_attention import ragged_causal_attention as pal_attn


def _mk_qkv(key, B, H, L, Dh, dtype):
    ks = jax.random.split(key, 3)
    mk = lambda k: jax.random.normal(k, (B, H, L, Dh), dtype)
    return mk(ks[0]), mk(ks[1]), mk(ks[2])


def _assert_valid_rows_close(o_pal, o_ref, lens, rtol, atol):
    for b, n in enumerate(np.asarray(lens)):
        np.testing.assert_allclose(
            np.asarray(o_pal[b, :, :n]), np.asarray(o_ref[b, :, :n]),
            rtol=rtol, atol=atol)


class TestRaggedAttention:
    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(1, 5),
        H=st.sampled_from([1, 2, 4]),
        nblk=st.integers(1, 4),
        Dh=st.sampled_from([8, 16, 32]),
        block_k=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random_lengths(self, B, H, nblk, Dh, block_k, seed):
        L = nblk * block_k
        key = jax.random.PRNGKey(seed)
        q, k, v = _mk_qkv(key, B, H, L, Dh, jnp.float32)
        lens = jax.random.randint(jax.random.fold_in(key, 1), (B,), 1, L + 1,
                                  jnp.int32)
        o_ref = ref.ragged_causal_attention(q, k, v, lens)
        o_pal = pal_attn(q, k, v, lens, block_k=block_k)
        _assert_valid_rows_close(o_pal, o_ref, lens, 2e-5, 2e-5)

    def test_full_length(self):
        key = jax.random.PRNGKey(0)
        q, k, v = _mk_qkv(key, 2, 2, 64, 16, jnp.float32)
        lens = jnp.array([64, 64], jnp.int32)
        o_ref = ref.ragged_causal_attention(q, k, v, lens)
        o_pal = pal_attn(q, k, v, lens)
        _assert_valid_rows_close(o_pal, o_ref, lens, 2e-5, 2e-5)

    def test_length_one(self):
        key = jax.random.PRNGKey(1)
        q, k, v = _mk_qkv(key, 3, 1, 32, 8, jnp.float32)
        lens = jnp.array([1, 1, 1], jnp.int32)
        o_pal = pal_attn(q, k, v, lens)
        # with a single valid token, output row 0 == v row 0
        np.testing.assert_allclose(np.asarray(o_pal[:, :, 0]),
                                   np.asarray(v[:, :, 0]), rtol=1e-5, atol=1e-5)

    def test_causality(self):
        """Perturbing future tokens must not change earlier outputs."""
        key = jax.random.PRNGKey(2)
        q, k, v = _mk_qkv(key, 1, 2, 64, 16, jnp.float32)
        lens = jnp.array([64], jnp.int32)
        o1 = pal_attn(q, k, v, lens)
        k2 = k.at[:, :, 40:].add(3.0)
        v2 = v.at[:, :, 40:].add(-2.0)
        o2 = pal_attn(q, k2, v2, lens)
        np.testing.assert_allclose(np.asarray(o1[:, :, :40]),
                                   np.asarray(o2[:, :, :40]), rtol=1e-5,
                                   atol=1e-5)

    def test_length_mask_blocks_padding(self):
        """Tokens beyond lens must not affect valid rows."""
        key = jax.random.PRNGKey(3)
        q, k, v = _mk_qkv(key, 2, 1, 32, 8, jnp.float32)
        lens = jnp.array([10, 20], jnp.int32)
        o1 = pal_attn(q, k, v, lens)
        k2 = k.at[0, :, 10:].set(99.0)
        v2 = v.at[0, :, 10:].set(-99.0)
        o2 = pal_attn(q, k2, v2, lens)
        np.testing.assert_allclose(np.asarray(o1[0, :, :10]),
                                   np.asarray(o2[0, :, :10]), rtol=1e-5,
                                   atol=1e-5)

    def test_rejects_non_multiple_block(self):
        key = jax.random.PRNGKey(0)
        q, k, v = _mk_qkv(key, 1, 1, 48, 8, jnp.float32)
        with pytest.raises(ValueError):
            pal_attn(q, k, v, jnp.array([48], jnp.int32), block_k=32)

    def test_rows_are_finite_even_when_padded(self):
        key = jax.random.PRNGKey(4)
        q, k, v = _mk_qkv(key, 1, 1, 32, 8, jnp.float32)
        o = pal_attn(q, k, v, jnp.array([3], jnp.int32))
        assert np.isfinite(np.asarray(o)).all()


class TestKldSignal:
    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(1, 6),
        K=st.integers(1, 13),
        V=st.sampled_from([32, 128, 256]),
        scale=st.floats(0.1, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, B, K, V, scale, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        tl = scale * jax.random.normal(k1, (B, K, V), jnp.float32)
        dl = scale * jax.random.normal(k2, (B, K, V), jnp.float32)
        kld_r, ent_r = ref.kld_signal(tl, dl)
        kld_p, ent_p = pal_kld(tl, dl)
        np.testing.assert_allclose(np.asarray(kld_p), np.asarray(kld_r),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(ent_p), np.asarray(ent_r),
                                   rtol=3e-5, atol=3e-5)

    def test_identical_dists_zero_kld(self):
        key = jax.random.PRNGKey(0)
        tl = jax.random.normal(key, (2, 4, 256), jnp.float32)
        kld, ent = pal_kld(tl, tl)
        np.testing.assert_allclose(np.asarray(kld), 0.0, atol=1e-5)
        assert (np.asarray(ent) > 0).all()

    def test_kld_nonnegative(self):
        key = jax.random.PRNGKey(5)
        k1, k2 = jax.random.split(key)
        tl = 3 * jax.random.normal(k1, (4, 8, 256), jnp.float32)
        dl = 3 * jax.random.normal(k2, (4, 8, 256), jnp.float32)
        kld, _ = pal_kld(tl, dl)
        assert (np.asarray(kld) >= -1e-5).all()

    def test_uniform_draft_entropy_is_logv(self):
        V = 128
        tl = jnp.zeros((1, 1, V), jnp.float32)
        _, ent = pal_kld(tl, tl)
        np.testing.assert_allclose(np.asarray(ent)[0, 0], np.log(V), rtol=1e-5)

    def test_shift_invariance(self):
        """Logits shifted by a constant give identical signals."""
        key = jax.random.PRNGKey(6)
        k1, k2 = jax.random.split(key)
        tl = jax.random.normal(k1, (2, 3, 64), jnp.float32)
        dl = jax.random.normal(k2, (2, 3, 64), jnp.float32)
        a = pal_kld(tl, dl)
        b = pal_kld(tl + 7.5, dl - 3.25)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=1e-4, atol=1e-5)
