"""AOT path tests: HLO text emission, weights file format, manifest schema,
and an in-python execute of the lowered HLO (the same computation Rust runs).
"""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

SMALL = M.ModelConfig("unit-aot", n_layers=1, d_model=32, n_heads=2,
                      d_ff=64, max_len=64)


class TestWeightsFormat:
    def test_roundtrip(self, tmp_path):
        vec = np.arange(17, dtype=np.float32) * 0.5
        p = str(tmp_path / "w.wts")
        aot.write_weights(p, vec)
        with open(p, "rb") as f:
            blob = f.read()
        assert blob[:8] == aot.WTS_MAGIC
        (n,) = struct.unpack("<Q", blob[8:16])
        assert n == 17
        back = np.frombuffer(blob[16:], dtype="<f4")
        np.testing.assert_array_equal(back, vec)

    def test_size_matches_header(self, tmp_path):
        p = str(tmp_path / "w.wts")
        aot.write_weights(p, np.zeros(100, np.float32))
        assert os.path.getsize(p) == 8 + 8 + 400


class TestLowering:
    def test_step_hlo_is_text(self):
        txt = aot.lower_step(SMALL, 2, use_pallas=False)
        assert "ENTRY" in txt and "HloModule" in txt

    def test_verify_hlo_is_text(self):
        txt = aot.lower_verify(SMALL, 2, use_pallas=False)
        assert "ENTRY" in txt

    def test_pallas_lowering_contains_no_custom_call(self):
        """interpret=True must lower to plain HLO the CPU PJRT can run."""
        txt = aot.lower_step(SMALL, 1, use_pallas=True)
        assert "custom-call" not in txt.lower() or "mosaic" not in txt.lower()

    def test_lowered_hlo_text_reparses(self):
        """The emitted text must parse back into an HLO module — the same
        parse the Rust runtime's ``HloModuleProto::from_text_file`` performs.
        (Numerical round-trip through PJRT is validated on the Rust side by
        ``rust/tests/pjrt_roundtrip.rs``.)"""
        from jax._src.lib import xla_client as xc
        txt = aot.lower_step(SMALL, 2, use_pallas=False)
        mod = xc._xla.hlo_module_from_text(txt)
        assert mod is not None
        # entry computation has our 3 params
        assert "parameter(2)" in txt

    def test_verify_outputs_are_3tuple(self):
        txt = aot.lower_verify(SMALL, 1, use_pallas=False)
        # ROOT of the entry is a tuple of (tlogits, kld, ent) per return_tuple
        assert txt.count("parameter(4)") >= 1


class TestManifest:
    def test_schema(self):
        m = aot.build_manifest((1, 4))
        assert m["vocab"] == 256
        assert m["pad_id"] == M.PAD_ID
        assert m["spec_k"] == M.SPEC_K
        assert m["buckets"] == [1, 4]
        assert m["models"]["target"]["n_params"] == M.n_params(M.TARGET_CFG)
        assert m["models"]["draft"]["n_params"] == M.n_params(M.DRAFT_CFG)
        json.dumps(m)  # serializable

    def test_bucket_templates(self):
        m = aot.build_manifest((1,))
        assert "{B}" in m["models"]["target"]["step"]
        assert "{B}" in m["models"]["target"]["verify"]
        assert "{B}" in m["models"]["draft"]["step"]
