"""L2 model tests: shapes, packing contract, entry-point semantics, and
Pallas-vs-ref agreement at the whole-graph level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SMALL = M.ModelConfig("unit-small", n_layers=2, d_model=32, n_heads=2,
                      d_ff=64, max_len=64)


@pytest.fixture(scope="module")
def small_params():
    return M.init_params(SMALL, jax.random.PRNGKey(0))


class TestPacking:
    def test_roundtrip(self, small_params):
        vec = M.pack_params(SMALL, small_params)
        assert vec.shape == (M.n_params(SMALL),)
        back = M.unpack_params(SMALL, vec)
        for name, _ in M.param_shapes(SMALL):
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(small_params[name]))

    def test_n_params_matches_shapes(self):
        total = sum(int(np.prod(s)) for _, s in M.param_shapes(SMALL))
        assert total == M.n_params(SMALL)

    def test_configs_are_sane(self):
        for cfg in (M.TARGET_CFG, M.DRAFT_CFG):
            assert cfg.d_model % cfg.n_heads == 0
            assert cfg.max_len % 32 == 0
            assert M.n_params(cfg) > 0
        assert M.n_params(M.TARGET_CFG) > 2 * M.n_params(M.DRAFT_CFG)


class TestForward:
    def test_logits_shape(self, small_params):
        toks = jnp.zeros((3, SMALL.max_len), jnp.int32)
        lens = jnp.array([5, 20, 64], jnp.int32)
        out = M.forward(SMALL, small_params, toks, lens, use_pallas=False)
        assert out.shape == (3, SMALL.max_len, SMALL.vocab)

    def test_pallas_ref_agree(self, small_params):
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (2, SMALL.max_len), 0, 256, jnp.int32)
        lens = jnp.array([30, 64], jnp.int32)
        a = M.forward(SMALL, small_params, toks, lens, use_pallas=False)
        b = M.forward(SMALL, small_params, toks, lens, use_pallas=True)
        for i, n in enumerate([30, 64]):
            np.testing.assert_allclose(np.asarray(a[i, :n]),
                                       np.asarray(b[i, :n]),
                                       rtol=5e-4, atol=5e-4)

    def test_causality_of_logits(self, small_params):
        """Changing token t must not change logits before t."""
        key = jax.random.PRNGKey(2)
        toks = jax.random.randint(key, (1, SMALL.max_len), 1, 256, jnp.int32)
        lens = jnp.array([50], jnp.int32)
        a = M.forward(SMALL, small_params, toks, lens, use_pallas=False)
        toks2 = toks.at[0, 30].set(7)
        b = M.forward(SMALL, small_params, toks2, lens, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a[0, :30]), np.asarray(b[0, :30]),
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(a[0, 30]), np.asarray(b[0, 30]))


class TestStepFn:
    def test_step_gathers_last_position(self, small_params):
        vec = M.pack_params(SMALL, small_params)
        key = jax.random.PRNGKey(3)
        toks = jax.random.randint(key, (2, SMALL.max_len), 1, 256, jnp.int32)
        lens = jnp.array([7, 33], jnp.int32)
        out = M.step_fn(SMALL, vec, toks, lens, use_pallas=False)
        full = M.forward(SMALL, small_params, toks, lens, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(full[0, 6]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(full[1, 32]),
                                   rtol=1e-5, atol=1e-5)

    def test_step_independent_of_padding(self, small_params):
        """Bytes beyond lens must not change the step logits."""
        vec = M.pack_params(SMALL, small_params)
        key = jax.random.PRNGKey(4)
        toks = jax.random.randint(key, (1, SMALL.max_len), 1, 256, jnp.int32)
        lens = jnp.array([12], jnp.int32)
        a = M.step_fn(SMALL, vec, toks, lens, use_pallas=False)
        toks2 = toks.at[0, 12:].set(M.PAD_ID)
        b = M.step_fn(SMALL, vec, toks2, lens, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


class TestVerifyFn:
    def test_shapes_and_signal_consistency(self, small_params):
        vec = M.pack_params(SMALL, small_params)
        key = jax.random.PRNGKey(5)
        B, K = 2, M.SPEC_K
        toks = jax.random.randint(key, (B, SMALL.max_len), 1, 256, jnp.int32)
        ctx = jnp.array([10, 20], jnp.int32)
        att = ctx + 4
        dlog = jax.random.normal(key, (B, K, SMALL.vocab), jnp.float32)
        tl, kld, ent = M.verify_fn(SMALL, vec, toks, ctx, att, dlog,
                                   use_pallas=False)
        assert tl.shape == (B, K + 1, SMALL.vocab)
        assert kld.shape == (B, K)
        assert ent.shape == (B, K)
        # kld of identical logits is 0
        tl2, kld2, _ = M.verify_fn(SMALL, vec, toks, ctx, att,
                                   tl[:, :K, :], use_pallas=False)
        np.testing.assert_allclose(np.asarray(kld2), 0.0, atol=1e-4)

    def test_verify_matches_step_chain(self, small_params):
        """Verify logits at slot j must equal a step call at ctx+j.

        This is the invariant the whole speculative pipeline rests on: one
        batched verify pass scores the same distributions the target would
        produce token-by-token.
        """
        vec = M.pack_params(SMALL, small_params)
        key = jax.random.PRNGKey(6)
        toks = jax.random.randint(key, (1, SMALL.max_len), 1, 256, jnp.int32)
        ctx = jnp.array([9], jnp.int32)
        k_drafted = 3
        att = ctx + k_drafted
        dlog = jnp.zeros((1, M.SPEC_K, SMALL.vocab), jnp.float32)
        tl, _, _ = M.verify_fn(SMALL, vec, toks, ctx, att, dlog,
                               use_pallas=False)
        for j in range(k_drafted + 1):
            step = M.step_fn(SMALL, vec, toks, ctx + j, use_pallas=False)
            np.testing.assert_allclose(np.asarray(tl[0, j]),
                                       np.asarray(step[0]),
                                       rtol=2e-4, atol=2e-4)

    def test_pallas_ref_agree_on_verify(self, small_params):
        vec = M.pack_params(SMALL, small_params)
        key = jax.random.PRNGKey(7)
        toks = jax.random.randint(key, (2, SMALL.max_len), 1, 256, jnp.int32)
        ctx = jnp.array([15, 8], jnp.int32)
        att = ctx + 5
        dlog = jax.random.normal(key, (2, M.SPEC_K, SMALL.vocab), jnp.float32)
        a = M.verify_fn(SMALL, vec, toks, ctx, att, dlog, use_pallas=False)
        b = M.verify_fn(SMALL, vec, toks, ctx, att, dlog, use_pallas=True)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                                   rtol=1e-3, atol=1e-3)


class TestLosses:
    def test_lm_loss_finite_and_decreases_on_repetition(self, small_params):
        toks = jnp.tile(jnp.arange(32, dtype=jnp.int32), (2, 2))
        loss = M.lm_loss(SMALL, small_params, toks)
        assert np.isfinite(float(loss))

    def test_distill_loss_zero_kl_for_self(self, small_params):
        """Distilling a model onto itself: KL term vanishes."""
        toks = jnp.tile(jnp.arange(32, dtype=jnp.int32), (1, 2))
        full = M.distill_loss(SMALL, small_params, SMALL, small_params, toks,
                              alpha=0.0)
        np.testing.assert_allclose(float(full), 0.0, atol=1e-4)
