"""Corpus generator determinism + short-budget training sanity.

The training sanity test doubles as the acceptance-regime check: the
distilled draft must agree with the target (low KL) far more than the
shifted-corpus draft — this is what creates the paper's two regimes
(LLaMA-like high acceptance vs Gemma-like low acceptance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model as M, train as T


class TestCorpus:
    def test_deterministic(self):
        assert corpus.build_corpus(seed=0, target_bytes=4096) == \
            corpus.build_corpus(seed=0, target_bytes=4096)

    def test_seeds_differ(self):
        assert corpus.build_corpus(seed=0, target_bytes=4096) != \
            corpus.build_corpus(seed=1, target_bytes=4096)

    def test_size_and_ascii(self):
        c = corpus.build_corpus(seed=0, target_bytes=8192)
        assert len(c) == 8192
        assert max(c) < 128  # pure ASCII -> byte vocab is well-covered

    def test_shifted_differs(self):
        a = corpus.build_corpus(seed=0, target_bytes=4096)
        b = corpus.build_shifted_corpus(seed=1, target_bytes=4096)
        # code keyword density differs strongly between the two corpora
        assert a.count(b"def ") > 5 * max(b.count(b"def "), 1) or \
            b.count(b"def ") == 0

    def test_prompt_kinds(self):
        for kind in ("code", "dialogue", "math", "prose"):
            p = corpus.sample_prompt(kind, seed=3, n_bytes=48)
            assert len(p) == 48

    def test_prompt_deterministic(self):
        assert corpus.sample_prompt("code", 5) == corpus.sample_prompt("code", 5)


SMALL = M.ModelConfig("unit-train", n_layers=1, d_model=32, n_heads=2,
                      d_ff=64, max_len=64)


class TestTraining:
    @pytest.fixture(scope="class")
    def data(self):
        return corpus.build_corpus(seed=0, target_bytes=1 << 15)

    def test_loss_decreases(self, data):
        import copy
        params0 = M.init_params(SMALL, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = T.windows(data, rng, 8, 64)
        loss0 = float(M.lm_loss(SMALL, params0, toks))
        params = T.train_lm(SMALL, data, steps=25, lr=3e-3, seed=0,
                            log_every=100)
        loss1 = float(M.lm_loss(SMALL, params, toks))
        assert loss1 < loss0 - 0.5, (loss0, loss1)

    def test_adam_moves_params(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        opt = T.adam_init(params)
        grads = jax.tree.map(jnp.ones_like, params)
        new, opt2 = T.adam_update(params, grads, opt, lr=1e-2)
        assert float(jnp.abs(new["embed"] - params["embed"]).max()) > 1e-4
        assert int(opt2["t"]) == 1

    def test_windows_shape_and_range(self, data):
        rng = np.random.default_rng(1)
        w = T.windows(data, rng, 4, 32)
        assert w.shape == (4, 32)
        assert int(w.min()) >= 0 and int(w.max()) < 256
