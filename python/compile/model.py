"""L2: byte-level transformer LM pair (target + draft) in functional JAX.

Stand-in for the paper's LLaMA-3.1-70B / LLaMA-3.2-1B (and Gemma-27B/2B)
pairs — see DESIGN.md §1 for the substitution argument.  The architecture is
a standard pre-norm transformer (RMSNorm, learned positions, GELU MLP, tied
embedding head) over a byte vocabulary (V=256).

Two attention implementations share one contract:
  * ``ref.ragged_causal_attention``   — pure jnp, used for training (fast)
  * ``ragged_attention`` Pallas kernel — used in the AOT serving graphs
The pytest suite asserts they agree, so the trained weights are valid for
the Pallas-backed serving graphs.

Serving entry points (lowered per batch bucket by aot.py; PJRT executables
are pure functions, so the full padded context is re-forwarded each call —
at L=160 this is cheaper than threading KV state through the artifact
interface, and the Rust engine still owns *logical* paged KV accounting):

  ``step(wvec, tokens[B,L], lens[B]) -> logits[B,V]``
      next-token logits at position ``lens[b]-1`` (predicting token
      ``lens[b]``).  Used by the draft worker (one call per drafted token)
      and by the autoregressive baseline.

  ``verify(wvec, tokens[B,L], ctx_lens[B], att_lens[B], draft_logits[B,K,V])
        -> (tlogits[B,K+1,V], kld[B,K], ent[B,K])``
      target logits at positions ``ctx_lens[b]-1+j`` for j in 0..K
      (scoring the K drafted tokens + the bonus position), plus the fused
      KLD/entropy signals from the Pallas kld_stats kernel.

All weights travel as ONE flat f32 vector (``wvec``) so the Rust runtime
passes a single opaque parameter buffer; (un)packing is defined here and
mirrored by the manifest.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.ragged_attention import ragged_causal_attention as pallas_attn
from .kernels.kld_stats import kld_signal as pallas_kld

VOCAB = 256
PAD_ID = 0          # reserved padding token id (paper §3.2)
MAX_LEN = 160       # padded context length (must be multiple of block_k=32)
SPEC_K = 12         # static K of the verify graph (>= any runtime SL)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    max_len: int = MAX_LEN
    vocab: int = VOCAB

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TARGET_CFG = ModelConfig("tiny-target", n_layers=4, d_model=128, n_heads=4, d_ff=352)
DRAFT_CFG = ModelConfig("tiny-draft", n_layers=2, d_model=64, n_heads=2, d_ff=176)


# ----------------------------------------------------------------------------
# parameter pytree <-> flat vector
# ----------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the packing order contract."""
    d, h, f = cfg.d_model, cfg.n_heads, cfg.d_ff
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, d)),
        ("pos", (cfg.max_len, d)),
    ]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.w2", (f, d)),
        ]
    shapes.append(("ln_f", (d,)))
    return shapes


def n_params(cfg: ModelConfig) -> int:
    return sum(int(math.prod(s)) for _, s in param_shapes(cfg))


def init_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    params: Dict[str, jax.Array] = {}
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "pos":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def pack_params(cfg: ModelConfig, params: Dict[str, jax.Array]) -> jax.Array:
    return jnp.concatenate(
        [params[n].reshape(-1) for n, _ in param_shapes(cfg)])


def unpack_params(cfg: ModelConfig, wvec: jax.Array) -> Dict[str, jax.Array]:
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        size = int(math.prod(shape))
        params[name] = jax.lax.dynamic_slice(wvec, (off,), (size,)).reshape(shape)
        off += size
    return params


# ----------------------------------------------------------------------------
# forward pass
# ----------------------------------------------------------------------------

def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + 1e-6)


def forward(cfg: ModelConfig, params: Dict[str, jax.Array], tokens, lens,
            *, use_pallas: bool) -> jax.Array:
    """Per-position logits ``[B, L, V]`` over padded byte contexts."""
    B, L = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :L, :]
    attn_fn = pallas_attn if use_pallas else kref.ragged_causal_attention
    for i in range(cfg.n_layers):
        h = _rmsnorm(x, params[f"l{i}.ln1"])
        q = (h @ params[f"l{i}.wq"]).reshape(B, L, cfg.n_heads, cfg.d_head)
        k = (h @ params[f"l{i}.wk"]).reshape(B, L, cfg.n_heads, cfg.d_head)
        v = (h @ params[f"l{i}.wv"]).reshape(B, L, cfg.n_heads, cfg.d_head)
        q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))   # [B,H,L,Dh]
        o = attn_fn(q, k, v, lens)
        o = o.transpose(0, 2, 1, 3).reshape(B, L, cfg.d_model)
        x = x + o @ params[f"l{i}.wo"]
        h = _rmsnorm(x, params[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
    x = _rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T                                  # tied head


# ----------------------------------------------------------------------------
# serving entry points (the functions aot.py lowers)
# ----------------------------------------------------------------------------

def step_fn(cfg: ModelConfig, wvec, tokens, lens, *, use_pallas: bool = True):
    """Next-token logits at position ``lens-1`` for each sequence: [B, V]."""
    params = unpack_params(cfg, wvec)
    logits = forward(cfg, params, tokens, lens, use_pallas=use_pallas)
    idx = jnp.clip(lens - 1, 0, cfg.max_len - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0, :]


def verify_fn(cfg: ModelConfig, wvec, tokens, ctx_lens, att_lens,
              draft_logits, *, k: int = SPEC_K, use_pallas: bool = True):
    """Target verification + fused signal computation.

    ``tokens`` already contains the drafted tokens appended after the context
    (padded with PAD_ID beyond each sequence's own k_i up to K).  Gathers
    target logits at positions ``ctx_lens-1 .. ctx_lens-1+K`` — scoring the K
    drafted slots plus the bonus position — and feeds the first K together
    with the draft logits through the Pallas kld_stats kernel.
    """
    params = unpack_params(cfg, wvec)
    logits = forward(cfg, params, tokens, att_lens, use_pallas=use_pallas)
    base = jnp.clip(ctx_lens - 1, 0, cfg.max_len - 1)             # [B]
    offs = jnp.arange(k + 1, dtype=jnp.int32)[None, :]            # [1, K+1]
    idx = jnp.clip(base[:, None] + offs, 0, cfg.max_len - 1)      # [B, K+1]
    tlogits = jnp.take_along_axis(logits, idx[:, :, None], axis=1)  # [B,K+1,V]
    if use_pallas:
        kld, ent = pallas_kld(tlogits[:, :k, :], draft_logits)
    else:
        kld, ent = kref.kld_signal(tlogits[:, :k, :], draft_logits)
    return tlogits, kld, ent


# ----------------------------------------------------------------------------
# training loss helpers (used by train.py; ref attention only)
# ----------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, tokens):
    """Causal LM cross-entropy over full windows ``[B, T]`` (no padding)."""
    B, T = tokens.shape
    lens = jnp.full((B,), T, jnp.int32)
    logits = forward(cfg, params, tokens, lens, use_pallas=False)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=-1)[:, :, 0]
    return nll.mean()


def distill_loss(cfg_d: ModelConfig, params_d, cfg_t: ModelConfig, params_t,
                 tokens, alpha: float = 0.5, temp: float = 1.0):
    """CE + KL(target || draft) distillation loss for the *good* draft."""
    B, T = tokens.shape
    lens = jnp.full((B,), T, jnp.int32)
    d_logits = forward(cfg_d, params_d, tokens, lens, use_pallas=False)
    t_logits = forward(cfg_t, params_t, tokens, lens, use_pallas=False)
    t_logits = jax.lax.stop_gradient(t_logits)
    logq = jax.nn.log_softmax(d_logits[:, :-1, :] / temp, axis=-1)
    logp = jax.nn.log_softmax(t_logits[:, :-1, :] / temp, axis=-1)
    kl = (jnp.exp(logp) * (logp - logq)).sum(-1).mean()
    tgt = tokens[:, 1:]
    ce = -jnp.take_along_axis(logq, tgt[:, :, None], axis=-1).mean()
    return alpha * ce + (1 - alpha) * kl
