"""Deterministic synthetic corpus generator.

The paper's evaluation exercises *task heterogeneity*: code-like text (low
entropy, highly predictable -> high draft acceptance) vs dialogue/prose (high
entropy -> low acceptance).  We reproduce that axis with a generated corpus:

- ``code``      : a tiny expression-language grammar with heavy repetition
                  (keywords, indentation, common idioms).
- ``prose``     : templated sentences with sampled content words.
- ``dialogue``  : turn-taking prose with speaker tags.
- ``math``      : GSM8K-like arithmetic word problems with worked solutions.

Everything is seeded and byte-level (vocab = 256), so artifact builds are
reproducible bit-for-bit.
"""

from __future__ import annotations

import random

# ----------------------------------------------------------------------------
# code grammar
# ----------------------------------------------------------------------------

_IDENTS = ["count", "total", "idx", "value", "result", "item", "size", "key",
           "node", "left", "right", "sum", "acc", "buf", "data", "queue"]
_FUNCS = ["compute", "process", "update", "merge", "split", "reduce",
          "lookup", "insert", "remove", "scan"]
_OPS = ["+", "-", "*", "%"]
_CMPS = ["<", ">", "<=", ">=", "=="]


def _gen_expr(rng: random.Random, depth: int = 0) -> str:
    if depth > 1 or rng.random() < 0.55:
        if rng.random() < 0.6:
            return rng.choice(_IDENTS)
        return str(rng.randint(0, 64))
    a = _gen_expr(rng, depth + 1)
    b = _gen_expr(rng, depth + 1)
    return f"{a} {rng.choice(_OPS)} {b}"


def _gen_stmt(rng: random.Random, indent: int) -> str:
    pad = "    " * indent
    r = rng.random()
    if r < 0.35:
        return f"{pad}{rng.choice(_IDENTS)} = {_gen_expr(rng)}\n"
    if r < 0.55:
        return (f"{pad}for {rng.choice(_IDENTS)} in range({rng.randint(1, 32)}):\n"
                + _gen_stmt(rng, indent + 1))
    if r < 0.75:
        return (f"{pad}if {rng.choice(_IDENTS)} {rng.choice(_CMPS)} {_gen_expr(rng)}:\n"
                + _gen_stmt(rng, indent + 1))
    if r < 0.9:
        return f"{pad}return {_gen_expr(rng)}\n"
    return f"{pad}{rng.choice(_IDENTS)} = {rng.choice(_FUNCS)}({rng.choice(_IDENTS)})\n"


def gen_code(rng: random.Random, n_funcs: int) -> str:
    out = []
    for _ in range(n_funcs):
        name = rng.choice(_FUNCS)
        arg = rng.choice(_IDENTS)
        out.append(f"def {name}({arg}):\n")
        for _ in range(rng.randint(2, 5)):
            out.append(_gen_stmt(rng, 1))
        out.append("\n")
    return "".join(out)


# ----------------------------------------------------------------------------
# prose / dialogue templates
# ----------------------------------------------------------------------------

_SUBJECTS = ["the system", "a model", "the report", "our team", "the city",
             "a study", "the market", "the network", "the device", "the plan"]
_VERBS = ["shows", "describes", "improves", "reduces", "handles", "explains",
          "predicts", "measures", "supports", "changes"]
_OBJECTS = ["the results", "a new method", "the overall cost", "user demand",
            "the main problem", "future growth", "the core design",
            "daily traffic", "total output", "the final outcome"]
_ADVS = ["quickly", "slowly", "clearly", "roughly", "notably", "barely",
         "often", "rarely", "directly", "partly"]
_SPEAKERS = ["User", "Agent"]


def gen_prose(rng: random.Random, n_sents: int) -> str:
    sents = []
    for _ in range(n_sents):
        s = (f"{rng.choice(_SUBJECTS)} {rng.choice(_VERBS)} "
             f"{rng.choice(_OBJECTS)} {rng.choice(_ADVS)}")
        sents.append(s[0].upper() + s[1:] + ". ")
    return "".join(sents) + "\n"


def gen_dialogue(rng: random.Random, n_turns: int) -> str:
    out = []
    for t in range(n_turns):
        out.append(f"{_SPEAKERS[t % 2]}: {gen_prose(rng, rng.randint(1, 3))}")
    return "".join(out)


def gen_math(rng: random.Random, n_problems: int) -> str:
    out = []
    for _ in range(n_problems):
        a, b, c = rng.randint(2, 40), rng.randint(2, 40), rng.randint(2, 12)
        out.append(
            f"Q: A box holds {a} items and another holds {b} items. "
            f"Each item costs {c}. What is the total cost?\n"
            f"A: {a} + {b} = {a + b}. {a + b} * {c} = {(a + b) * c}. "
            f"The total cost is {(a + b) * c}.\n\n")
    return "".join(out)


# ----------------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------------

def build_corpus(seed: int = 0, target_bytes: int = 1 << 18) -> bytes:
    """Mixed corpus: ~40% code, 25% prose, 20% dialogue, 15% math."""
    rng = random.Random(seed)
    chunks = []
    size = 0
    while size < target_bytes:
        r = rng.random()
        if r < 0.40:
            c = gen_code(rng, rng.randint(2, 4))
        elif r < 0.65:
            c = gen_prose(rng, rng.randint(4, 10))
        elif r < 0.85:
            c = gen_dialogue(rng, rng.randint(2, 6))
        else:
            c = gen_math(rng, rng.randint(1, 3))
        chunks.append(c)
        size += len(c)
    return "".join(chunks).encode("ascii", errors="replace")[:target_bytes]


def build_shifted_corpus(seed: int = 1, target_bytes: int = 1 << 18) -> bytes:
    """A distribution-shifted corpus (math+dialogue heavy, different seed) used
    to train the *weak* draft — reproducing the paper's high-divergence
    Gemma-27B/2B regime."""
    rng = random.Random(seed)
    chunks = []
    size = 0
    while size < target_bytes:
        r = rng.random()
        if r < 0.5:
            c = gen_math(rng, rng.randint(2, 4))
        else:
            c = gen_dialogue(rng, rng.randint(3, 8))
        chunks.append(c)
        size += len(c)
    return "".join(chunks).encode("ascii", errors="replace")[:target_bytes]


def sample_prompt(kind: str, seed: int, n_bytes: int = 48) -> bytes:
    """A prompt of the given task kind (used by tests and the e2e example)."""
    rng = random.Random(seed)
    if kind == "code":
        text = gen_code(rng, 2)
    elif kind == "dialogue":
        text = gen_dialogue(rng, 3)
    elif kind == "math":
        text = gen_math(rng, 2)
    else:
        text = gen_prose(rng, 6)
    b = text.encode("ascii", errors="replace")
    return b[:n_bytes].ljust(n_bytes, b" ")


if __name__ == "__main__":
    c = build_corpus()
    print(f"corpus bytes: {len(c)}")
    print(c[:400].decode())
