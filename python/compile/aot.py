"""AOT lowering: JAX serving graphs → HLO **text** artifacts + manifest.

Run once by ``make artifacts``; Rust (the request path) only ever touches the
emitted files.  Interchange format is HLO text, NOT a serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published `xla` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Outputs (under artifacts/):
  manifest.json                         — shapes, buckets, model configs
  target.wts / draft_good.wts / draft_weak.wts
                                        — packed f32 weight vectors (DSDW1 fmt)
  target_step_b{B}.hlo.txt              — AR-baseline / target step
  target_verify_b{B}.hlo.txt            — ragged verify + fused KLD signals
  draft_step_b{B}.hlo.txt               — draft step (weights are an input, so
                                          one graph serves both draft models)
for B in BUCKETS.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

BUCKETS = (1, 2, 4, 8, 16, 32, 64)
WTS_MAGIC = b"DSDW1\0\0\0"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def write_weights(path: str, wvec: np.ndarray) -> None:
    """DSDW1 format: 8-byte magic, u64 little-endian count, f32 LE data."""
    wvec = np.asarray(wvec, dtype=np.float32).reshape(-1)
    with open(path, "wb") as f:
        f.write(WTS_MAGIC)
        f.write(struct.pack("<Q", wvec.size))
        f.write(wvec.tobytes())


def lower_step(cfg: M.ModelConfig, batch: int, use_pallas: bool) -> str:
    fn = functools.partial(M.step_fn, cfg, use_pallas=use_pallas)
    w = jax.ShapeDtypeStruct((M.n_params(cfg),), jnp.float32)
    toks = jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(w, toks, lens))


def lower_verify(cfg: M.ModelConfig, batch: int, use_pallas: bool) -> str:
    fn = functools.partial(M.verify_fn, cfg, use_pallas=use_pallas)
    w = jax.ShapeDtypeStruct((M.n_params(cfg),), jnp.float32)
    toks = jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    dlog = jax.ShapeDtypeStruct((batch, M.SPEC_K, cfg.vocab), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(w, toks, lens, lens, dlog))


def build_manifest(buckets) -> dict:
    return {
        "format": "dsde-artifacts-v1",
        "vocab": M.VOCAB,
        "pad_id": M.PAD_ID,
        "max_len": M.MAX_LEN,
        "spec_k": M.SPEC_K,
        "buckets": list(buckets),
        "models": {
            "target": {
                "n_params": M.n_params(M.TARGET_CFG),
                "n_layers": M.TARGET_CFG.n_layers,
                "d_model": M.TARGET_CFG.d_model,
                "weights": "target.wts",
                "step": "target_step_b{B}.hlo.txt",
                "verify": "target_verify_b{B}.hlo.txt",
            },
            "draft": {
                "n_params": M.n_params(M.DRAFT_CFG),
                "n_layers": M.DRAFT_CFG.n_layers,
                "d_model": M.DRAFT_CFG.d_model,
                "weights": {"good": "draft_good.wts", "weak": "draft_weak.wts"},
                "step": "draft_step_b{B}.hlo.txt",
            },
        },
        "step_io": {
            "inputs": ["wvec[P] f32", "tokens[B,L] i32", "lens[B] i32"],
            "outputs": ["logits[B,V] f32"],
        },
        "verify_io": {
            "inputs": ["wvec[P] f32", "tokens[B,L] i32", "ctx_lens[B] i32",
                       "att_lens[B] i32", "draft_logits[B,K,V] f32"],
            "outputs": ["tlogits[B,K+1,V] f32", "kld[B,K] f32", "ent[B,K] f32"],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--buckets", default=",".join(map(str, BUCKETS)))
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the ref attention instead of the Pallas "
                         "kernels (perf A/B ablation)")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training budget (CI / smoke builds)")
    ap.add_argument("--steps", type=int, default=None,
                    help="override target training steps")
    args = ap.parse_args()

    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    use_pallas = not args.no_pallas

    # ---- train the pair (build-time only) -----------------------------------
    from . import train as T
    if args.fast:
        st, sd, sw = 40, 30, 20
    else:
        st, sd, sw = 300, 250, 150
    if args.steps:
        st = args.steps
        sd = max(args.steps * 5 // 6, 1)
        sw = max(args.steps // 2, 1)
    wt, wg, ww = T.train_all(steps_target=st, steps_draft=sd, steps_weak=sw)
    write_weights(os.path.join(outdir, "target.wts"), np.asarray(wt))
    write_weights(os.path.join(outdir, "draft_good.wts"), np.asarray(wg))
    write_weights(os.path.join(outdir, "draft_weak.wts"), np.asarray(ww))

    # ---- lower graphs --------------------------------------------------------
    for b in buckets:
        for name, text in (
            (f"target_step_b{b}.hlo.txt", lower_step(M.TARGET_CFG, b, use_pallas)),
            (f"target_verify_b{b}.hlo.txt", lower_verify(M.TARGET_CFG, b, use_pallas)),
            (f"draft_step_b{b}.hlo.txt", lower_step(M.DRAFT_CFG, b, use_pallas)),
        ):
            path = os.path.join(outdir, name)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {name} ({len(text) / 1024:.0f} KiB)", flush=True)

    manifest = build_manifest(buckets)
    manifest["pallas"] = use_pallas
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
