"""Build-time training of the tiny model pair (runs once in `make artifacts`).

Produces three weight vectors (saved under artifacts/):
  * ``target``      — 4-layer target LM trained on the mixed corpus.
  * ``draft_good``  — 2-layer draft distilled from the target on the same
                      corpus (CE + KL to target logits). High-acceptance pair
                      — the paper's LLaMA-70B/1B regime.
  * ``draft_weak``  — 2-layer draft trained on a distribution-shifted corpus
                      with no distillation. High-divergence pair — the
                      paper's Gemma-27B/2B low-acceptance regime (§4.4).

Optimizer is a hand-rolled Adam (optax is not available in this image).
Everything is seeded; the artifact build is reproducible.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import model as M

TRAIN_LEN = 128
BATCH = 24


# ----------------------------------------------------------------------------
# Adam
# ----------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}

def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) /
        (jnp.sqrt(v_ * vhat_scale) + eps), params, m, v)
    return params, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------------
# data
# ----------------------------------------------------------------------------

def windows(data: bytes, rng: np.random.Generator, batch: int, length: int):
    arr = np.frombuffer(data, dtype=np.uint8)
    starts = rng.integers(0, len(arr) - length - 1, size=batch)
    return jnp.asarray(
        np.stack([arr[s:s + length] for s in starts]).astype(np.int32))


# ----------------------------------------------------------------------------
# training loops
# ----------------------------------------------------------------------------

def train_lm(cfg: M.ModelConfig, data: bytes, steps: int, lr: float,
             seed: int, log_every: int = 50) -> Dict[str, jax.Array]:
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    train_len = min(TRAIN_LEN, cfg.max_len)

    @jax.jit
    def step(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    for i in range(steps):
        toks = windows(data, rng, BATCH, train_len)
        cur_lr = lr * min(1.0, (i + 1) / 30) * (0.5 ** (i / max(steps, 1) * 2))
        params, opt, loss = step(params, opt, toks, cur_lr)
        if i % log_every == 0 or i == steps - 1:
            print(f"[train {cfg.name}] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


def distill_draft(cfg_d: M.ModelConfig, cfg_t: M.ModelConfig, params_t,
                  data: bytes, steps: int, lr: float, seed: int,
                  log_every: int = 50) -> Dict[str, jax.Array]:
    params = M.init_params(cfg_d, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    train_len = min(TRAIN_LEN, cfg_d.max_len, cfg_t.max_len)

    @jax.jit
    def step(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(
            lambda p: M.distill_loss(cfg_d, p, cfg_t, params_t, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    for i in range(steps):
        toks = windows(data, rng, BATCH, train_len)
        cur_lr = lr * min(1.0, (i + 1) / 30) * (0.5 ** (i / max(steps, 1) * 2))
        params, opt, loss = step(params, opt, toks, cur_lr)
        if i % log_every == 0 or i == steps - 1:
            print(f"[distill {cfg_d.name}] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


def train_all(steps_target: int = 300, steps_draft: int = 250,
              steps_weak: int = 150) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns packed weight vectors (target, draft_good, draft_weak)."""
    mixed = corpus_mod.build_corpus(seed=0)
    shifted = corpus_mod.build_shifted_corpus(seed=1)
    params_t = train_lm(M.TARGET_CFG, mixed, steps_target, lr=2e-3, seed=7)
    params_dg = distill_draft(M.DRAFT_CFG, M.TARGET_CFG, params_t, mixed,
                              steps_draft, lr=3e-3, seed=11)
    params_dw = train_lm(M.DRAFT_CFG, shifted, steps_weak, lr=3e-3, seed=13)
    return (M.pack_params(M.TARGET_CFG, params_t),
            M.pack_params(M.DRAFT_CFG, params_dg),
            M.pack_params(M.DRAFT_CFG, params_dw))


if __name__ == "__main__":
    wt, wg, ww = train_all(steps_target=60, steps_draft=40, steps_weak=30)
    print("target params:", wt.shape, "draft:", wg.shape)
