"""L1 Pallas kernel: fused post-verification signal computation.

The DSDE SL-Adapter consumes, per verified position, the Kullback–Leibler
divergence between the target and draft next-token distributions plus the
draft entropy (the AdaEDL baseline's signal).  Computing these naively takes
three softmax passes over [B, K, V] logits; this kernel fuses
log-softmax(p), log-softmax(q), KL(p||q) and H(q) into a single VMEM-resident
pass per batch row — it is the signal-path hot-spot that runs inside the
target-verify HLO on every engine step.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kld_kernel(p_ref, q_ref, kld_ref, ent_ref):
    """One batch row: p_ref/q_ref [K, V] logits → kld_ref/ent_ref [K]."""
    p = p_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    pm = p.max(axis=-1, keepdims=True)
    qm = q.max(axis=-1, keepdims=True)
    ps = p - pm
    qs = q - qm
    logzp = jnp.log(jnp.exp(ps).sum(axis=-1, keepdims=True))
    logzq = jnp.log(jnp.exp(qs).sum(axis=-1, keepdims=True))
    logp = ps - logzp
    logq = qs - logzq
    pp = jnp.exp(logp)
    qq = jnp.exp(logq)
    kld_ref[...] = (pp * (logp - logq)).sum(axis=-1).astype(kld_ref.dtype)
    ent_ref[...] = (-(qq * logq).sum(axis=-1)).astype(ent_ref.dtype)


def kld_signal(target_logits, draft_logits, *, interpret: bool = True):
    """Fused KL(p_target || q_draft) and H(q_draft) per position.

    Args:
      target_logits, draft_logits: ``[B, K, V]`` float arrays.

    Returns:
      ``(kld, entropy)`` each ``[B, K]`` float32.
    """
    B, K, V = target_logits.shape
    spec = pl.BlockSpec((None, K, V), lambda b: (b, 0, 0))
    ospec = pl.BlockSpec((None, K), lambda b: (b, 0))
    return pl.pallas_call(
        _kld_kernel,
        grid=(B,),
        in_specs=[spec, spec],
        out_specs=[ospec, ospec],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        interpret=interpret,
    )(target_logits, draft_logits)
