"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: ``python/tests/`` asserts the Pallas
implementations (interpret=True) match these within tolerance, and the L2
training path uses them directly (training never pays the interpret-mode
overhead; only the AOT serving graphs embed the Pallas kernels).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ragged_causal_attention(q, k, v, lens, *, scale=None):
    """Causal multi-head attention over padded sequences.

    Args:
      q, k, v: ``[B, H, L, Dh]`` float arrays.
      lens:    ``[B]`` int32 — valid length per sequence; keys at positions
               ``>= lens[b]`` are padding and must not be attended.
      scale:   optional softmax scale (defaults to ``1/sqrt(Dh)``).

    Returns:
      ``[B, H, L, Dh]`` attention output.  Rows at padded query positions are
      normalized against key 0 only (they are never read downstream).
    """
    B, H, L, Dh = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    pos = jnp.arange(L)
    causal = pos[None, :] <= pos[:, None]                 # [Lq, Lk]
    keyok = pos[None, :] < lens[:, None]                  # [B, Lk]
    mask = causal[None, None, :, :] & keyok[:, None, None, :]
    # Guarantee at least one valid key per row (key 0) to avoid 0/0 on
    # padded query rows; those rows are masked out by callers.
    mask = mask.at[:, :, :, 0].set(True)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _log_softmax(x):
    m = x.max(axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.exp(s).sum(axis=-1, keepdims=True))


def kld_signal(target_logits, draft_logits):
    """Fused post-verification signal computation (oracle).

    Args:
      target_logits: ``[B, K, V]`` — target logits at the drafted positions.
      draft_logits:  ``[B, K, V]`` — draft logits at the same positions.

    Returns:
      ``(kld, draft_entropy)`` each ``[B, K]`` where
      ``kld[b, j]     = KL( P_target(.|ctx_j)  ||  Q_draft(.|ctx_j) )`` and
      ``entropy[b, j] = H( Q_draft(.|ctx_j) )``.
    """
    logp = _log_softmax(target_logits)
    logq = _log_softmax(draft_logits)
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    kld = (p * (logp - logq)).sum(axis=-1)
    entropy = -(q * logq).sum(axis=-1)
    return kld, entropy
