"""L1 Pallas kernel: ragged-Q causal flash attention.

This is the TPU rethink of the FlashAttention-2 *varlen* CUDA kernel the paper
integrates into vLLM's target worker (§3 "variable-length kernel of
FlashAttention-2 ... allowing requests with heterogeneous speculative lengths
to be processed efficiently within a single batch").

GPU → TPU mapping (DESIGN.md §Hardware-Adaptation):
  * FA2 threadblock per (sequence, head)    → Pallas grid = (B, H)
  * SRAM K/V tiles + online softmax         → VMEM K/V blocks streamed via a
    fori_loop with running (m, l, acc) state — the HBM↔VMEM schedule is the
    BlockSpec + in-kernel block loop
  * cu_seqlens ragged packing               → padded [B, L] layout + per-seq
    length mask (TPU wants the regular layout; raggedness is a mask)

The kernel must be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers the kernel to
plain HLO that any backend runs.  Real-TPU perf is therefore *estimated*
(DESIGN.md §6), not measured.

Perf note (EXPERIMENTS.md §Perf): under interpret-mode CPU execution the
block loop materializes as an HLO while-loop; block_k=16 measured fastest
for L=160 at B=8 (74 ms vs 83 ms at block_k=32 for the whole verify graph).
On a real TPU the tradeoff inverts toward larger VMEM tiles — block_k is a
parameter precisely so the schedule can be retuned per backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                 seq_len: int, scale: float):
    """One (batch, head) attention problem.

    Refs (one grid step): q_ref/k_ref/v_ref: [L, Dh]; len_ref: [1] int32;
    o_ref: [L, Dh].
    """
    seq_valid = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * scale          # [L, Dh]
    L, Dh = q.shape
    n_blocks = seq_len // block_k
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (L, block_k), 0)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        start = kb * block_k
        kblk = k_ref[pl.dslice(start, block_k), :].astype(jnp.float32)
        vblk = v_ref[pl.dslice(start, block_k), :].astype(jnp.float32)
        s = q @ kblk.T                                   # [L, block_k]
        col_ids = start + jax.lax.broadcasted_iota(jnp.int32, (L, block_k), 1)
        mask = (col_ids <= row_ids) & (col_ids < seq_valid)
        # key 0 always valid: keeps padded query rows finite (never read).
        mask = mask | (col_ids == 0)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))      # [L]
        corr = jnp.exp(m_prev - m_cur)                   # [L]
        p = jnp.exp(s - m_cur[:, None])                  # [L, block_k]
        l_cur = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + p @ vblk
        return m_cur, l_cur, acc

    m0 = jnp.full((L,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((L,), jnp.float32)
    acc0 = jnp.zeros((L, Dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def ragged_causal_attention(q, k, v, lens, *, scale=None, block_k: int = 16,
                            interpret: bool = True):
    """Pallas ragged-Q causal attention; same contract as the ref oracle.

    Args:
      q, k, v: ``[B, H, L, Dh]``; ``L`` must be a multiple of ``block_k``.
      lens: ``[B]`` int32 valid lengths.
    """
    B, H, L, Dh = q.shape
    if L % block_k != 0:
        raise ValueError(f"L={L} must be a multiple of block_k={block_k}")
    if scale is None:
        scale = 1.0 / float(Dh) ** 0.5
    kern = functools.partial(_attn_kernel, block_k=block_k, seq_len=L,
                             scale=float(scale))
    grid = (B, H)
    bspec = pl.BlockSpec((None, None, L, Dh), lambda b, h: (b, h, 0, 0))
    lspec = pl.BlockSpec((1,), lambda b, h: (b,))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[lspec, bspec, bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct((B, H, L, Dh), q.dtype),
        interpret=interpret,
    )(lens, q, k, v)
