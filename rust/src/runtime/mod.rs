//! L3↔L2 bridge: loads the AOT artifacts emitted by `python/compile/aot.py`
//! (HLO text + packed weights + manifest) and executes them on the PJRT CPU
//! client via the `xla` crate.  This is the only module that touches PJRT;
//! everything above it speaks [`crate::model::traits::SpecModel`].
//!
//! Design notes:
//! * Interchange is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5's
//!   64-bit-id serialized protos; the text parser reassigns ids.
//! * Weights are packed into a single f32 vector per model (`.wts` files,
//!   DSDW1 format) and uploaded to the device **once**; per-step calls only
//!   move tokens/lengths/logits (hot-path allocation is O(batch)).
//! * Executables are compiled lazily per (function, batch-bucket) and
//!   memoized; the engine pads its batch up to the nearest bucket.

pub mod artifacts;
pub mod exec;

pub use artifacts::{Manifest, WeightsFile};
pub use exec::{PjrtContext, StepOutput, VerifyOutput};
