//! PJRT execution context: lazy compile + memoized executables + uploaded
//! weight buffers.  One `PjrtContext` owns everything PJRT for a model pair.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::artifacts::{DraftKind, Manifest, WeightsFile};
use crate::log_info;

/// Which lowered graph to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GraphKind {
    /// Target model single-token step (the AR baseline's forward).
    TargetStep,
    /// Target model ragged batched verify along K draft slots.
    TargetVerify,
    /// Draft model single-token step (one speculative micro-step).
    DraftStep,
}

/// Output of a `step` graph: next-token logits, row-major `[B, V]`.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Flattened `[batch * vocab]` logits.
    pub logits: Vec<f32>,
    /// Batch (bucket) dimension.
    pub batch: usize,
    /// Vocabulary dimension.
    pub vocab: usize,
}

impl StepOutput {
    /// Logits row for sequence `b`.
    pub fn row(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }
}

/// Output of a `verify` graph.
#[derive(Clone, Debug)]
pub struct VerifyOutput {
    /// Target logits `[B, K+1, V]` at the drafted positions + bonus slot.
    pub tlogits: Vec<f32>,
    /// Fused KL(p_target || q_draft) per drafted slot, `[B, K]`.
    pub kld: Vec<f32>,
    /// Fused draft entropy per drafted slot, `[B, K]`.
    pub entropy: Vec<f32>,
    /// Batch (bucket) dimension.
    pub batch: usize,
    /// Speculation-length dimension (the graph's static K).
    pub k: usize,
    /// Vocabulary dimension.
    pub vocab: usize,
}

impl VerifyOutput {
    /// Target logits for sequence `b`, slot `j` (j in 0..=K; K is bonus).
    pub fn tlogits_row(&self, b: usize, j: usize) -> &[f32] {
        let base = (b * (self.k + 1) + j) * self.vocab;
        &self.tlogits[base..base + self.vocab]
    }

    /// Fused KLD signal for sequence `b`, drafted slot `j`.
    pub fn kld_at(&self, b: usize, j: usize) -> f32 {
        self.kld[b * self.k + j]
    }

    /// Fused draft entropy for sequence `b`, drafted slot `j`.
    pub fn entropy_at(&self, b: usize, j: usize) -> f32 {
        self.entropy[b * self.k + j]
    }
}

/// PJRT CPU context for the artifact set: compiles lazily per
/// (graph, bucket), keeps weights resident on device.
pub struct PjrtContext {
    /// The artifact manifest this context was loaded from.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<(GraphKind, usize), xla::PjRtLoadedExecutable>,
    target_w: xla::PjRtBuffer,
    draft_w: xla::PjRtBuffer,
    /// Cumulative host↔device + execute seconds, for the perf log.
    pub exec_seconds: f64,
    /// Number of graph executions performed.
    pub exec_calls: u64,
}

impl PjrtContext {
    /// Load manifest + weights and bring up the PJRT CPU client.
    pub fn new(artifact_dir: impl AsRef<Path>, draft: DraftKind) -> Result<PjrtContext> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        log_info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let tw = WeightsFile::load(manifest.weights_path("target"))?;
        anyhow::ensure!(
            tw.len() == manifest.target_n_params,
            "target weights {} != manifest {}",
            tw.len(),
            manifest.target_n_params
        );
        let dname = match draft {
            DraftKind::Good => "draft_good",
            DraftKind::Weak => "draft_weak",
        };
        let dw = WeightsFile::load(manifest.weights_path(dname))?;
        anyhow::ensure!(
            dw.len() == manifest.draft_n_params,
            "draft weights {} != manifest {}",
            dw.len(),
            manifest.draft_n_params
        );
        let target_w = client
            .buffer_from_host_buffer(&tw.data, &[tw.len()], None)
            .map_err(|e| anyhow!("upload target weights: {e:?}"))?;
        let draft_w = client
            .buffer_from_host_buffer(&dw.data, &[dw.len()], None)
            .map_err(|e| anyhow!("upload draft weights: {e:?}"))?;
        Ok(PjrtContext {
            manifest,
            client,
            exes: HashMap::new(),
            target_w,
            draft_w,
            exec_seconds: 0.0,
            exec_calls: 0,
        })
    }

    /// Pre-compile the graphs for a bucket (e.g. at server startup).
    pub fn warmup(&mut self, bucket: usize) -> Result<()> {
        self.ensure_compiled(GraphKind::DraftStep, bucket)?;
        self.ensure_compiled(GraphKind::TargetVerify, bucket)?;
        Ok(())
    }

    fn ensure_compiled(&mut self, kind: GraphKind, bucket: usize) -> Result<()> {
        if self.exes.contains_key(&(kind, bucket)) {
            return Ok(());
        }
        let path = match kind {
            GraphKind::TargetStep => self.manifest.target_step_path(bucket),
            GraphKind::TargetVerify => self.manifest.target_verify_path(bucket),
            GraphKind::DraftStep => self.manifest.draft_step_path(bucket),
        };
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        log_info!(
            "compiled {kind:?} bucket={bucket} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.exes.insert((kind, bucket), exe);
        Ok(())
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Run a step graph. `tokens` is `[bucket * max_len]` row-major i32,
    /// `lens` is `[bucket]`.  Returns `[bucket, V]` logits.
    pub fn step(
        &mut self,
        kind: GraphKind,
        bucket: usize,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<StepOutput> {
        assert!(matches!(kind, GraphKind::TargetStep | GraphKind::DraftStep));
        let l = self.manifest.max_len;
        let v = self.manifest.vocab;
        assert_eq!(tokens.len(), bucket * l, "tokens shape");
        assert_eq!(lens.len(), bucket, "lens shape");
        self.ensure_compiled(kind, bucket)?;
        let t0 = Instant::now();
        let tok_b = self.upload_i32(tokens, &[bucket, l])?;
        let len_b = self.upload_i32(lens, &[bucket])?;
        let wbuf = match kind {
            GraphKind::DraftStep => &self.draft_w,
            _ => &self.target_w,
        };
        let exe = &self.exes[&(kind, bucket)];
        let outs = exe
            .execute_b(&[wbuf, &tok_b, &len_b])
            .map_err(|e| anyhow!("execute step: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch step output: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("untuple step output: {e:?}"))?;
        let logits = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("step logits to_vec: {e:?}"))?;
        debug_assert_eq!(logits.len(), bucket * v);
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(StepOutput {
            logits,
            batch: bucket,
            vocab: v,
        })
    }

    /// Run the target verify graph.
    ///
    /// `tokens` already has the drafted tokens appended after each context;
    /// `ctx_lens[b]` is the pre-draft length (gather base), `att_lens[b] =
    /// ctx_lens[b] + k_b` bounds attention, `draft_logits` is `[bucket, K, V]`.
    pub fn verify(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        ctx_lens: &[i32],
        att_lens: &[i32],
        draft_logits: &[f32],
    ) -> Result<VerifyOutput> {
        let l = self.manifest.max_len;
        let v = self.manifest.vocab;
        let k = self.manifest.spec_k;
        assert_eq!(tokens.len(), bucket * l);
        assert_eq!(ctx_lens.len(), bucket);
        assert_eq!(att_lens.len(), bucket);
        assert_eq!(draft_logits.len(), bucket * k * v);
        self.ensure_compiled(GraphKind::TargetVerify, bucket)?;
        let t0 = Instant::now();
        let tok_b = self.upload_i32(tokens, &[bucket, l])?;
        let ctx_b = self.upload_i32(ctx_lens, &[bucket])?;
        let att_b = self.upload_i32(att_lens, &[bucket])?;
        let dl_b = self.upload_f32(draft_logits, &[bucket, k, v])?;
        let exe = &self.exes[&(GraphKind::TargetVerify, bucket)];
        let outs = exe
            .execute_b(&[&self.target_w, &tok_b, &ctx_b, &att_b, &dl_b])
            .map_err(|e| anyhow!("execute verify: {e:?}"))?;
        let (tl, kl, en) = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch verify output: {e:?}"))?
            .to_tuple3()
            .map_err(|e| anyhow!("untuple verify output: {e:?}"))?;
        let tlogits = tl.to_vec::<f32>().map_err(|e| anyhow!("tlogits: {e:?}"))?;
        let kld = kl.to_vec::<f32>().map_err(|e| anyhow!("kld: {e:?}"))?;
        let entropy = en.to_vec::<f32>().map_err(|e| anyhow!("entropy: {e:?}"))?;
        debug_assert_eq!(tlogits.len(), bucket * (k + 1) * v);
        debug_assert_eq!(kld.len(), bucket * k);
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(VerifyOutput {
            tlogits,
            kld,
            entropy,
            batch: bucket,
            k,
            vocab: v,
        })
    }

    /// Padded context length of the lowered graphs.
    pub fn max_len(&self) -> usize {
        self.manifest.max_len
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    /// Verify graph's static speculation-length ceiling K.
    pub fn spec_k(&self) -> usize {
        self.manifest.spec_k
    }

    /// Reserved padding token id.
    pub fn pad_id(&self) -> u32 {
        self.manifest.pad_id
    }

    /// Smallest lowered batch bucket that fits `batch`.
    pub fn bucket_for(&self, batch: usize) -> usize {
        self.manifest.bucket_for(batch)
    }
}

// SAFETY: PjrtContext is only ever *moved* into a single engine thread (the
// HTTP server funnels all requests through that thread via channels), so no
// PJRT object is ever accessed concurrently.  The underlying PJRT CPU client
// itself is documented thread-safe; the raw pointers in the `xla` wrappers
// are what inhibit the auto-impl.
unsafe impl Send for PjrtContext {}
