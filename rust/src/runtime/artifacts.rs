//! Artifact manifest + weights-file loading.
//!
//! `manifest.json` is written by `python/compile/aot.py` and describes the
//! model pair, the batch buckets the HLO graphs were lowered for, and the
//! file-name templates.  `.wts` files are DSDW1: 8-byte magic, u64 LE count,
//! f32 LE data — the packed parameter vector the step/verify graphs take as
//! their first argument.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// 8-byte magic prefix of a DSDW1 weights file.
pub const WTS_MAGIC: &[u8; 8] = b"DSDW1\0\0\0";

/// Which draft weights to load — the paper's two regimes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DraftKind {
    /// Distilled draft — high-acceptance (LLaMA-70B/1B-like) pair.
    Good,
    /// Shifted-corpus draft — low-acceptance (Gemma-27B/2B-like) pair (§4.4).
    Weak,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Vocabulary size the graphs were lowered for.
    pub vocab: usize,
    /// Reserved padding token id (paper §3.2).
    pub pad_id: u32,
    /// Padded context length of the lowered graphs.
    pub max_len: usize,
    /// Verify graph's static speculation-length ceiling K.
    pub spec_k: usize,
    /// Batch buckets the graphs were lowered for.
    pub buckets: Vec<usize>,
    /// Target model parameter count (weights-file validation).
    pub target_n_params: usize,
    /// Draft model parameter count (weights-file validation).
    pub draft_n_params: usize,
    /// File-name template of the target step graph (`{B}` = bucket).
    pub target_step_tpl: String,
    /// File-name template of the target verify graph.
    pub target_verify_tpl: String,
    /// File-name template of the draft step graph.
    pub draft_step_tpl: String,
    /// Target weights file name.
    pub target_weights: String,
    /// Distilled (high-acceptance) draft weights file name.
    pub draft_good_weights: String,
    /// Shifted-corpus (low-acceptance) draft weights file name.
    pub draft_weak_weights: String,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let need = |p: &[&str]| -> Result<&Json> {
            j.at(p).ok_or_else(|| anyhow!("manifest missing {}", p.join(".")))
        };
        let fmt = need(&["format"])?.as_str().unwrap_or_default();
        if fmt != "dsde-artifacts-v1" {
            bail!("unsupported artifact format {fmt:?}");
        }
        let buckets = need(&["buckets"])?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets not an array"))?
            .iter()
            .filter_map(|b| b.as_usize())
            .collect::<Vec<_>>();
        if buckets.is_empty() {
            bail!("manifest has no batch buckets");
        }
        let draft_w = need(&["models", "draft", "weights"])?;
        Ok(Manifest {
            vocab: need(&["vocab"])?.as_usize().unwrap_or(256),
            pad_id: need(&["pad_id"])?.as_usize().unwrap_or(0) as u32,
            max_len: need(&["max_len"])?.as_usize().unwrap_or(160),
            spec_k: need(&["spec_k"])?.as_usize().unwrap_or(12),
            buckets,
            target_n_params: need(&["models", "target", "n_params"])?
                .as_usize()
                .unwrap_or(0),
            draft_n_params: need(&["models", "draft", "n_params"])?
                .as_usize()
                .unwrap_or(0),
            target_step_tpl: need(&["models", "target", "step"])?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            target_verify_tpl: need(&["models", "target", "verify"])?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            draft_step_tpl: need(&["models", "draft", "step"])?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            target_weights: need(&["models", "target", "weights"])?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            draft_good_weights: draft_w
                .get("good")
                .and_then(|x| x.as_str())
                .unwrap_or_default()
                .to_string(),
            draft_weak_weights: draft_w
                .get("weak")
                .and_then(|x| x.as_str())
                .unwrap_or_default()
                .to_string(),
            dir,
        })
    }

    /// Smallest lowered bucket that fits `batch`, or the largest available.
    pub fn bucket_for(&self, batch: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= batch)
            .min()
            .unwrap_or_else(|| *self.buckets.iter().max().unwrap())
    }

    /// Path of the target step graph lowered for `bucket`.
    pub fn target_step_path(&self, bucket: usize) -> PathBuf {
        self.dir.join(self.target_step_tpl.replace("{B}", &bucket.to_string()))
    }

    /// Path of the target verify graph lowered for `bucket`.
    pub fn target_verify_path(&self, bucket: usize) -> PathBuf {
        self.dir
            .join(self.target_verify_tpl.replace("{B}", &bucket.to_string()))
    }

    /// Path of the draft step graph lowered for `bucket`.
    pub fn draft_step_path(&self, bucket: usize) -> PathBuf {
        self.dir.join(self.draft_step_tpl.replace("{B}", &bucket.to_string()))
    }

    /// Path of a weights file: `target`, `draft_good`, or `draft_weak`
    /// (panics on anything else).
    pub fn weights_path(&self, which: &str) -> PathBuf {
        let name = match which {
            "target" => &self.target_weights,
            "draft_good" => &self.draft_good_weights,
            "draft_weak" => &self.draft_weak_weights,
            other => panic!("unknown weights {other:?}"),
        };
        self.dir.join(name)
    }
}

/// A loaded DSDW1 weights file.
#[derive(Clone, Debug)]
pub struct WeightsFile {
    /// The packed f32 parameter vector.
    pub data: Vec<f32>,
}

impl WeightsFile {
    /// Load and validate a DSDW1 file (magic, declared count, exact size).
    pub fn load(path: impl AsRef<Path>) -> Result<WeightsFile> {
        let path = path.as_ref();
        let blob = fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if blob.len() < 16 || &blob[..8] != WTS_MAGIC {
            bail!("{path:?}: not a DSDW1 weights file");
        }
        let n = u64::from_le_bytes(blob[8..16].try_into().unwrap()) as usize;
        let want = 16 + n * 4;
        if blob.len() != want {
            bail!("{path:?}: size {} != expected {want}", blob.len());
        }
        let mut data = Vec::with_capacity(n);
        for chunk in blob[16..].chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(WeightsFile { data })
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the file held zero parameters.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dsde-test-{tag}-{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_manifest(dir: &Path, extra: &str) {
        let text = format!(
            r#"{{
              "format": "dsde-artifacts-v1",
              "vocab": 256, "pad_id": 0, "max_len": 160, "spec_k": 12,
              "buckets": [1, 4, 16],
              "models": {{
                "target": {{"n_params": 100, "weights": "t.wts",
                            "step": "ts_b{{B}}.hlo.txt", "verify": "tv_b{{B}}.hlo.txt"}},
                "draft": {{"n_params": 50,
                           "weights": {{"good": "dg.wts", "weak": "dw.wts"}},
                           "step": "ds_b{{B}}.hlo.txt"}}
              }}{extra}
            }}"#
        );
        fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn manifest_parses_and_resolves_paths() {
        let d = tmpdir("manifest");
        write_manifest(&d, "");
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.buckets, vec![1, 4, 16]);
        assert_eq!(m.target_n_params, 100);
        assert!(m.target_step_path(4).ends_with("ts_b4.hlo.txt"));
        assert!(m.draft_step_path(16).ends_with("ds_b16.hlo.txt"));
        assert!(m.weights_path("draft_weak").ends_with("dw.wts"));
        fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bucket_selection() {
        let d = tmpdir("bucket");
        write_manifest(&d, "");
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(2), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(9), 16);
        assert_eq!(m.bucket_for(64), 16); // clamps to largest lowered
        fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load("/nonexistent-dir-dsde").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn weights_roundtrip() {
        let d = tmpdir("wts");
        let path = d.join("w.wts");
        let vals: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect();
        let mut f = fs::File::create(&path).unwrap();
        f.write_all(WTS_MAGIC).unwrap();
        f.write_all(&(vals.len() as u64).to_le_bytes()).unwrap();
        for v in &vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let w = WeightsFile::load(&path).unwrap();
        assert_eq!(w.data, vals);
        fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn weights_rejects_bad_magic() {
        let d = tmpdir("badwts");
        let path = d.join("bad.wts");
        fs::write(&path, b"NOTMAGIC\0\0\0\0\0\0\0\0").unwrap();
        assert!(WeightsFile::load(&path).is_err());
        fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn weights_rejects_truncation() {
        let d = tmpdir("trunc");
        let path = d.join("t.wts");
        let mut blob = Vec::new();
        blob.extend_from_slice(WTS_MAGIC);
        blob.extend_from_slice(&5u64.to_le_bytes());
        blob.extend_from_slice(&[0u8; 8]); // only 2 floats of 5
        fs::write(&path, blob).unwrap();
        assert!(WeightsFile::load(&path).is_err());
        fs::remove_dir_all(&d).ok();
    }
}
