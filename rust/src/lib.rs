//! # DSDE — Dynamic Speculative Decoding with KLD Stability
//!
//! A from-scratch reproduction of *DSDE: Dynamic Speculative Decoding with
//! KLD Stability for Real-World Serving* (Yang et al., 2025) as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — a vLLM-like speculative-decoding engine:
//!   continuous batching, paged KV management, draft/target workers, exact
//!   rejection sampling, and the paper's contribution — the [`spec::adapter`]
//!   SL-Adapter (KLD-variance / WVIR signal) plus the adaptive
//!   [`spec::cap`] SL-cap for the straggler problem.
//! * **L2/L1 (build-time python)** — a tiny transformer pair with Pallas
//!   kernels, AOT-lowered to HLO text artifacts loaded by [`runtime`].
//!
//! Python never runs on the request path: after `make artifacts`, the
//! binaries in this crate are self-contained.

pub mod config;
pub mod repro;
pub mod engine;
pub mod model;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod spec;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::config::{
        AdapterConfig, CapMode, EngineConfig, RoutePolicy, RouterConfig, SlPolicyKind,
    };
    pub use crate::engine::engine::Engine;
    pub use crate::engine::metrics::{EngineMetrics, RequestMetrics};
    pub use crate::engine::request::{Request, SamplingParams};
    pub use crate::engine::step::{PlanOutcome, StepPlan, StepReport};
    pub use crate::model::sim_lm::{SimModel, SimPairKind};
    pub use crate::model::traits::SpecModel;
    pub use crate::server::router::EngineRouter;
    pub use crate::sim::regime::DatasetProfile;
    pub use crate::workload::{Dataset, WorkloadGen};
}
