//! # DSDE — Dynamic Speculative Decoding with KLD Stability
//!
//! A from-scratch reproduction of *DSDE: Dynamic Speculative Decoding with
//! KLD Stability for Real-World Serving* (Yang et al., 2025) as a
//! three-layer Rust + JAX + Pallas serving stack.
//!
//! Layer map (see `DESIGN.md` at the repository root for the full
//! architecture, including the streaming data flow):
//! * **L3 (this crate)** — a vLLM-like speculative-decoding engine:
//!   continuous batching, paged KV management, draft/target workers, exact
//!   rejection sampling, and the paper's contribution — the [`spec::adapter`]
//!   SL-Adapter (KLD-variance / WVIR signal) plus the adaptive
//!   [`spec::cap`] SL-cap for the straggler problem.  On top sits the
//!   [`server`] layer: a multi-replica router and an HTTP/1.1 front-end
//!   with blocking and token-streaming completions, selectable between a
//!   thread-per-connection and a sharded epoll/poll event-loop
//!   implementation (`--frontend`, `--poller`, `--loop-shards`),
//!   byte-identical either way.
//! * **L2/L1 (build-time python)** — a tiny transformer pair with Pallas
//!   kernels, AOT-lowered to HLO text artifacts loaded by [`runtime`].
//!
//! The [`eval`] subsystem (`pallas eval`) reproduces the paper's claims as
//! a structured experiment grid over this stack — datasets × SL policies ×
//! acceptance regimes × batch sizes, with serving-trace record/replay for
//! apples-to-apples configuration comparison (see `EVALUATION.md`).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! binaries in this crate are self-contained.
#![warn(missing_docs)]

pub mod config;
pub mod engine;
pub mod eval;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod spec;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::config::{
        AdapterConfig, CapMode, EngineConfig, FrontendKind, RoutePolicy, RouterConfig,
        SlPolicyKind, SpecControl,
    };
    pub use crate::engine::engine::{Engine, StepOutcome};
    pub use crate::engine::metrics::{EngineMetrics, MetricsSnapshot, RequestMetrics};
    pub use crate::engine::request::{Request, SamplingParams};
    pub use crate::engine::step::{PlanOutcome, StepPlan, StepReport, TokenDelta};
    pub use crate::model::sim_lm::{SimModel, SimPairKind};
    pub use crate::model::traits::SpecModel;
    pub use crate::server::router::{EngineRouter, StreamEvent};
    pub use crate::sim::regime::DatasetProfile;
    pub use crate::workload::{Dataset, WorkloadGen};
}
