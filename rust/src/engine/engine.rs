//! The engine: request lifecycle + the staged step loop — Fig. 4 of the
//! paper:
//!
//! ```text
//!   plan    | schedule (admit -> SL assignment -> cap -> KV look-ahead)
//!   execute | draft worker (k_i each) -> target worker (ragged verify)
//!           |   -> rejection sampler
//!   apply   | token/signal application -> SL adapter state -> retirement
//! ```
//!
//! The three stages live in [`super::step`] as `Engine::plan` /
//! `Engine::execute` / `Engine::apply` with typed [`super::step::StepPlan`]
//! and [`super::step::StepReport`] boundaries; [`Engine::step`] is the thin
//! driver that chains them.
//!
//! The engine is substrate-agnostic: the same loop runs over the PJRT model
//! (real forwards, wall-clock time) and the simulator (regime process,
//! virtual time).  Time is a single scalar clock: on the real path it
//! follows `Instant::elapsed`, on the simulated path it advances by each
//! round's modeled cost.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::kv_cache::KvCache;
use super::metrics::{EngineMetrics, RequestMetrics};
use super::request::{FinishReason, FinishedRequest, Request, SeqState};
use super::scheduler::Scheduler;
use super::step::{PlanOutcome, StepReport};
use crate::config::EngineConfig;
use crate::model::traits::SpecModel;
use crate::spec::adapter::{make_policy, SlPolicy};
use crate::spec::control::ControlCell;

/// A cheap cross-thread load snapshot of one engine replica, published by
/// the serving layer after every step and consumed by the router's
/// KV-aware placement and work-stealing decisions (see
/// [`crate::server::router::EngineRouter`]).  All fields are O(1) or
/// O(#waiting) to compute — nothing here touches the KV block tables.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaLoad {
    /// Sequences currently scheduled in the running batch.
    pub in_flight: usize,
    /// KV blocks currently mapped to sequences.
    pub kv_used_blocks: usize,
    /// KV blocks currently unallocated.
    pub kv_free_blocks: usize,
    /// Requests waiting in the engine's admission queue (not in-flight).
    pub queued_requests: usize,
    /// Projected token demand of the waiting queue: each queued sequence's
    /// current length (prompt + any pre-preemption output) plus its
    /// remaining output budget — the KV footprint it will grow to.
    pub queued_prompt_tokens: usize,
    /// Whether the serving layer has declared this replica failed (engine
    /// thread panicked or stopped heartbeating).  Always `false` when the
    /// snapshot comes straight from the engine; the router's supervisor
    /// sets it when it fails the replica over (see
    /// [`crate::server::router::EngineRouter`]).
    pub failed: bool,
}

/// What one driven engine step did (see [`Engine::step_detailed`]).
#[derive(Debug)]
pub enum StepOutcome {
    /// Nothing runnable and nothing that can become runnable on its own.
    Idle,
    /// Nothing ran this step but queued work may proceed on a later one.
    Retry,
    /// A round ran; the report carries its per-request token deltas.
    Ran(StepReport),
}

/// The speculative-decoding serving engine.
pub struct Engine {
    /// Engine configuration (validated at construction).
    pub cfg: EngineConfig,
    pub(crate) model: Box<dyn SpecModel>,
    pub(crate) policy: Box<dyn SlPolicy>,
    pub(crate) scheduler: Scheduler,
    pub(crate) kv: KvCache,
    pub(crate) waiting: VecDeque<SeqState>,
    pub(crate) running: Vec<SeqState>,
    pub(crate) finished: Vec<FinishedRequest>,
    /// Rolling engine metrics (see [`EngineMetrics`]).
    pub metrics: EngineMetrics,
    pub(crate) clock: f64,
    pub(crate) real_t0: Instant,
    pub(crate) uses_virtual_time: bool,
    pub(crate) control: Option<Arc<ControlCell>>,
}

impl Engine {
    /// Construct an engine with the policy named in the config.
    pub fn new(cfg: EngineConfig, model: Box<dyn SpecModel>) -> Engine {
        let policy = make_policy(&cfg.policy);
        Engine::with_policy(cfg, model, policy)
    }

    /// Construct with an explicit policy object (ablation variants and
    /// custom adapters that have no [`crate::config::SlPolicyKind`] tag).
    pub fn with_policy(
        cfg: EngineConfig,
        model: Box<dyn SpecModel>,
        policy: Box<dyn SlPolicy>,
    ) -> Engine {
        cfg.validate().expect("invalid engine config");
        let scheduler = Scheduler::new(cfg.max_batch);
        let kv = KvCache::new(cfg.kv_blocks, cfg.kv_block_size);
        let metrics = EngineMetrics::with_retention(cfg.metrics_retention);
        Engine {
            scheduler,
            kv,
            policy,
            model,
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            metrics,
            clock: 0.0,
            real_t0: Instant::now(),
            uses_virtual_time: false,
            control: None,
        }
    }

    /// Attach the fleet controller's per-replica actuator mailbox (see
    /// [`crate::spec::control::ControlCell`]).  The plan stage reads it
    /// once per step; with no cell attached (or a neutral cell) planning
    /// is bit-identical to an uncontrolled engine.
    pub fn set_control(&mut self, cell: Arc<ControlCell>) {
        self.control = Some(cell);
    }

    /// Current engine time (virtual or wall).
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Whether the engine has been advancing on simulator virtual time.
    pub fn is_virtual_time(&self) -> bool {
        self.uses_virtual_time
    }

    /// Queue a request.  `arrival` is backdated by any queue wait the
    /// request already accrued on another replica ([`Request::waited`]),
    /// so latency/TTFT survive a work-steal migration.
    pub fn submit(&mut self, mut req: Request) {
        req.arrival = self.clock - req.waited;
        self.waiting.push_back(SeqState::from_request(req));
    }

    /// Requests queued or running (not yet retired).
    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    /// Drain the finished-request buffer.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    /// Drive until all submitted requests complete; returns them.
    pub fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        while self.pending() > 0 {
            if !self.step().expect("engine step failed") {
                break;
            }
        }
        self.take_finished()
    }

    /// One engine step: the thin `plan → execute → apply` driver.  Returns
    /// false when there was nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        Ok(!matches!(self.step_detailed()?, StepOutcome::Idle))
    }

    /// One engine step, surfacing the [`StepReport`] when a round ran.
    /// This is the driver for callers that consume per-step output — the
    /// replica loop forwards [`super::step::TokenDelta`]s from the report
    /// to streaming subscribers.
    pub fn step_detailed(&mut self) -> Result<StepOutcome> {
        self.metrics.steps += 1;
        let plan = match self.plan() {
            PlanOutcome::Idle => return Ok(StepOutcome::Idle),
            PlanOutcome::Retry => return Ok(StepOutcome::Retry),
            PlanOutcome::Run(plan) => plan,
        };
        let round = self.execute(&plan)?;
        Ok(StepOutcome::Ran(self.apply(plan, round)))
    }

    pub(crate) fn retire(&mut self, seq: SeqState, reason: FinishReason) {
        self.kv.release(seq.id);
        self.model.release(seq.id);
        let fin = FinishedRequest {
            id: seq.id,
            output: seq.tokens[seq.prompt_len..].to_vec(),
            reason,
            arrival: seq.arrival,
            finished_at: self.clock,
            first_token_at: seq.first_token_at.unwrap_or(self.clock),
            rounds: seq.rounds,
            drafted: seq.signals.drafted_total,
            accepted: seq.signals.accepted_total,
            preemptions: seq.preemptions,
            tenant: seq.tenant,
            class: seq.class,
            deadline_ms: seq.deadline_ms,
        };
        self.metrics.record_request(RequestMetrics {
            id: fin.id,
            latency: fin.latency(),
            ttft: fin.ttft(),
            itl: fin.itl(),
            output_tokens: fin.output.len(),
            rounds: fin.rounds,
            drafted: fin.drafted,
            accepted: fin.accepted,
            preemptions: fin.preemptions,
            tenant: fin.tenant.clone(),
            class: fin.class,
            deadline_met: fin.deadline_met(),
        });
        self.finished.push(fin);
    }

    /// Abort only the head-of-line waiting request (used when the head can
    /// never be scheduled, e.g. its prompt exceeds total KV capacity, and
    /// FCFS forbids skipping it).  Returns the aborted id.
    pub fn abort_head(&mut self) -> Option<u64> {
        let seq = self.waiting.pop_front()?;
        let id = seq.id;
        self.retire(seq, FinishReason::Aborted);
        Some(id)
    }

    /// Abort all in-flight work (server shutdown).
    pub fn abort_all(&mut self) {
        let drained: Vec<SeqState> = self
            .running
            .drain(..)
            .chain(self.waiting.drain(..))
            .collect();
        for seq in drained {
            self.retire(seq, FinishReason::Aborted);
        }
    }

    /// Name of the active SL policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Name of the underlying model substrate.
    pub fn model_name(&self) -> String {
        self.model.name()
    }

    /// KV blocks currently mapped.
    pub fn kv_used_blocks(&self) -> usize {
        self.kv.used_blocks()
    }

    /// KV blocks currently unallocated.
    pub fn kv_free_blocks(&self) -> usize {
        self.kv.free_blocks()
    }

    /// Tokens per KV block (the paged-attention page size).
    pub fn kv_block_size(&self) -> usize {
        self.kv.block_size()
    }

    /// Requests waiting in the admission queue (not yet running).
    pub fn queued_requests(&self) -> usize {
        self.waiting.len()
    }

    /// Projected token demand of the waiting queue: current length plus
    /// remaining output budget per queued sequence (see
    /// [`ReplicaLoad::queued_prompt_tokens`]).
    ///
    /// O(#waiting) by design: the queue is mutated from several sites
    /// (admission, preemption re-queue, stealing, aborts), and a scan per
    /// step cannot drift the way an incrementally-maintained counter
    /// could.  Revisit with a counter if queue depths ever reach the tens
    /// of thousands.
    pub fn queued_prompt_tokens(&self) -> usize {
        self.waiting
            .iter()
            .map(|s| s.tokens.len() + s.remaining())
            .sum()
    }

    /// Snapshot the replica-load gauges the router's placement layer
    /// consumes (KV occupancy + queue pressure).
    pub fn load_snapshot(&self) -> ReplicaLoad {
        ReplicaLoad {
            in_flight: self.running.len(),
            kv_used_blocks: self.kv.used_blocks(),
            kv_free_blocks: self.kv.free_blocks(),
            queued_requests: self.waiting.len(),
            queued_prompt_tokens: self.queued_prompt_tokens(),
            failed: false,
        }
    }

    /// Migrate up to `max` *untouched* requests off the back of the waiting
    /// queue (work stealing).  Only sequences that have never run — no
    /// generated tokens, no rounds, no preemptions — are eligible: they
    /// carry no model or KV state, so they can restart on another replica
    /// without changing their output.  The front of the queue (FCFS head,
    /// preemption victims) is never stolen.  Returned requests preserve
    /// their arrival order and carry the queue wait accrued here
    /// ([`Request::waited`]), so the thief's latency accounting keeps
    /// counting it.
    pub fn steal_waiting(&mut self, max: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < max && self.waiting.len() > 1 {
            let eligible = self
                .waiting
                .back()
                .is_some_and(|s| s.rounds == 0 && s.generated() == 0 && s.preemptions == 0);
            if !eligible {
                break;
            }
            let seq = self.waiting.pop_back().unwrap();
            out.push(Request {
                id: seq.id,
                prompt: seq.tokens,
                params: seq.params,
                arrival: seq.arrival,
                waited: (self.clock - seq.arrival).max(0.0),
                tenant: seq.tenant,
                class: seq.class,
                deadline_ms: seq.deadline_ms,
            });
        }
        out.reverse();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SlPolicyKind;
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engine(policy: SlPolicyKind, speculative: bool) -> Engine {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 512,
            speculative,
            policy,
            seed: 7,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 7)
            .with_max_len(512);
        Engine::new(cfg, Box::new(model))
    }

    fn submit_n(e: &mut Engine, n: usize, max_tokens: usize) {
        for i in 0..n {
            e.submit(Request::new(
                i as u64,
                vec![65; 32],
                crate::engine::request::SamplingParams {
                    max_tokens,
                    ..Default::default()
                },
            ));
        }
    }

    #[test]
    fn completes_all_requests() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 6, 40);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 6);
        for r in &done {
            assert_eq!(r.output.len(), 40);
            assert_eq!(r.reason, FinishReason::MaxTokens);
            assert!(r.latency() > 0.0);
        }
    }

    #[test]
    fn autoregressive_mode_works() {
        let mut e = sim_engine(SlPolicyKind::Static(4), false);
        submit_n(&mut e, 2, 16);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 2);
        assert_eq!(e.metrics.ar_rounds, 16); // one token per round per seq
        assert_eq!(e.metrics.drafted, 0);
    }

    #[test]
    fn speculative_beats_autoregressive_on_virtual_time() {
        let mut ar = sim_engine(SlPolicyKind::Static(4), false);
        submit_n(&mut ar, 4, 64);
        ar.run_to_completion();
        let mut sp = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut sp, 4, 64);
        sp.run_to_completion();
        assert!(
            sp.metrics.mean_latency() < 0.7 * ar.metrics.mean_latency(),
            "spec {} vs ar {}",
            sp.metrics.mean_latency(),
            ar.metrics.mean_latency()
        );
    }

    #[test]
    fn dsde_policy_runs_and_calibrates() {
        let mut e = sim_engine(
            SlPolicyKind::Dsde(crate::spec::adapter::DsdeConfig::default()),
            true,
        );
        submit_n(&mut e, 3, 48);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 3);
        assert!(e.metrics.block_efficiency() > 1.0);
    }

    #[test]
    fn adaedl_policy_runs() {
        let mut e = sim_engine(
            SlPolicyKind::AdaEdl(crate::spec::adapter::AdaEdlConfig::default()),
            true,
        );
        submit_n(&mut e, 3, 32);
        assert_eq!(e.run_to_completion().len(), 3);
    }

    #[test]
    fn block_efficiency_reasonable_for_high_acceptance() {
        let mut e = sim_engine(SlPolicyKind::Static(8), true);
        submit_n(&mut e, 4, 96);
        e.run_to_completion();
        let be = e.metrics.block_efficiency();
        assert!(be > 2.0 && be < 7.0, "BE {be}");
    }

    #[test]
    fn kv_pressure_causes_preemption_but_everything_finishes() {
        let cfg = EngineConfig {
            max_batch: 8,
            max_len: 512,
            kv_blocks: 24, // tight: 24*16 = 384 token slots for 8 seqs
            speculative: true,
            policy: SlPolicyKind::Static(6),
            seed: 3,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 3)
            .with_max_len(512);
        let mut e = Engine::new(cfg, Box::new(model));
        submit_n(&mut e, 8, 48);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 8);
        let preempted: usize = done.iter().map(|r| r.preemptions).sum();
        assert!(preempted > 0, "expected KV preemptions under pressure");
        // scheduler outcome is wired into the engine metrics
        assert_eq!(e.metrics.preemptions, preempted as u64);
        // every preemption forces a re-admission, so admissions exceed n
        assert!(e.metrics.admitted >= 8 + preempted as u64);
    }

    #[test]
    fn admissions_tracked_without_pressure() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 6, 8);
        e.run_to_completion();
        assert_eq!(e.metrics.admitted, 6);
        assert_eq!(e.metrics.preemptions, 0);
    }

    #[test]
    fn max_tokens_never_exceeded() {
        let mut e = sim_engine(SlPolicyKind::Static(8), true);
        submit_n(&mut e, 5, 10);
        let done = e.run_to_completion();
        for r in &done {
            assert!(r.output.len() <= 10);
        }
    }

    #[test]
    fn step_detailed_surfaces_reports_until_idle() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 2, 12);
        let mut delta_tokens = 0usize;
        loop {
            match e.step_detailed().unwrap() {
                StepOutcome::Idle => break,
                StepOutcome::Retry => continue,
                StepOutcome::Ran(report) => {
                    delta_tokens +=
                        report.deltas.iter().map(|d| d.tokens.len()).sum::<usize>();
                }
            }
        }
        // the streamed deltas account for every emitted token
        assert_eq!(delta_tokens as u64, e.metrics.tokens_out);
        assert_eq!(e.take_finished().len(), 2);
    }

    #[test]
    fn request_metrics_carry_itl() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 2, 24);
        e.run_to_completion();
        assert_eq!(e.metrics.itl.count(), 2);
        assert!(e.metrics.itl.mean() > 0.0);
        assert!(e.metrics.ttft.mean() > 0.0);
    }

    #[test]
    fn virtual_clock_advances() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 1, 16);
        e.run_to_completion();
        assert!(e.now() > 0.0);
        assert!(e.metrics.busy_time > 0.0);
        assert!(e.is_virtual_time());
    }

    #[test]
    fn straggler_bubble_tracked_without_cap() {
        let cfg = EngineConfig {
            max_batch: 8,
            max_len: 512,
            speculative: true,
            policy: SlPolicyKind::Dsde(Default::default()),
            cap_mode: crate::config::CapMode::None,
            seed: 11,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::sharegpt(), 11)
            .with_max_len(512);
        let mut e = Engine::new(cfg, Box::new(model));
        submit_n(&mut e, 8, 64);
        e.run_to_completion();
        assert!(e.metrics.straggler_bubble > 0);
        // no cap -> the cap can never shave the round critical path
        assert_eq!(e.metrics.cap_savings, 0);
    }

    #[test]
    fn abort_head_pops_only_the_head() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 3, 8);
        assert_eq!(e.abort_head(), Some(0));
        assert_eq!(e.pending(), 2);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 3);
        let aborted: Vec<u64> = done
            .iter()
            .filter(|r| r.reason == FinishReason::Aborted)
            .map(|r| r.id)
            .collect();
        assert_eq!(aborted, vec![0]);
    }

    #[test]
    fn load_snapshot_tracks_queue_and_kv() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        let snap = e.load_snapshot();
        assert_eq!(snap.in_flight, 0);
        assert_eq!(snap.queued_requests, 0);
        assert_eq!(snap.kv_used_blocks, 0);
        assert_eq!(snap.kv_free_blocks, e.cfg.kv_blocks);
        submit_n(&mut e, 3, 16);
        let snap = e.load_snapshot();
        assert_eq!(snap.in_flight, 0, "nothing admitted before a step");
        assert_eq!(snap.queued_requests, 3);
        // 3 waiting seqs, each 32 prompt tokens + 16 budget
        assert_eq!(snap.queued_prompt_tokens, 3 * (32 + 16));
        assert_eq!(snap.kv_used_blocks, 0);
        assert_eq!(snap.kv_free_blocks + snap.kv_used_blocks, e.cfg.kv_blocks);
        e.step().unwrap();
        let snap = e.load_snapshot();
        assert_eq!(snap.in_flight, 3, "all admitted into the batch");
        assert_eq!(snap.queued_requests, 0);
        assert!(snap.kv_used_blocks > 0, "running seqs hold KV");
        e.run_to_completion();
        let snap = e.load_snapshot();
        assert_eq!(snap.kv_used_blocks, 0, "drained engine frees all KV");
        assert_eq!(snap.in_flight, 0);
    }

    #[test]
    fn steal_waiting_takes_untouched_tail_preserving_order() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 5, 8); // ids 0..5, all waiting and untouched
        let stolen = e.steal_waiting(3);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 3, 4],
            "steal pops the tail but preserves arrival order"
        );
        assert_eq!(e.pending(), 2);
        // the head (FCFS front) is never stolen even when asked for more
        let stolen = e.steal_waiting(10);
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(e.pending(), 1);
        assert!(e.steal_waiting(10).is_empty());
        // stolen requests are whole: prompt + params intact
        assert_eq!(stolen[0].prompt, vec![65; 32]);
        assert_eq!(stolen[0].params.max_tokens, 8);
    }

    #[test]
    fn steal_waiting_skips_started_sequences() {
        // a preempted sequence (re-queued at the front with history) must
        // never migrate: its regime/KV trajectory is replica-local
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 2, 8);
        let mut victim = e.waiting.pop_front().unwrap();
        victim.preemptions = 1;
        e.waiting.push_back(victim); // started seq at the tail
        assert!(
            e.steal_waiting(2).is_empty(),
            "a preempted tail blocks stealing behind it"
        );
        assert_eq!(e.pending(), 2);
    }

    #[test]
    fn abort_drains_everything() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 4, 1000);
        e.step().unwrap();
        e.abort_all();
        assert_eq!(e.pending(), 0);
        let done = e.take_finished();
        assert_eq!(done.len(), 4);
        assert!(done.iter().any(|r| r.reason == FinishReason::Aborted));
    }
}
