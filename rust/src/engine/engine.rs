//! The engine step loop — Fig. 4 of the paper:
//!
//! ```text
//!   schedule -> draft worker (k_i each) -> target worker (ragged verify)
//!     -> rejection sampler -> SL adapter (signals -> SL_i^{(t+1)})
//!     -> look-ahead scheduler (KV pre-mapping for the next round)
//! ```
//!
//! The engine is substrate-agnostic: the same loop runs over the PJRT model
//! (real forwards, wall-clock time) and the simulator (regime process,
//! virtual time).  Time is a single scalar clock: on the real path it
//! follows `Instant::elapsed`, on the simulated path it advances by each
//! round's modeled cost.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::kv_cache::KvCache;
use super::metrics::{EngineMetrics, RequestMetrics};
use super::request::{FinishReason, FinishedRequest, Request, SeqState};
use super::scheduler::Scheduler;
use crate::config::EngineConfig;
use crate::model::traits::{SeqInput, SpecModel};
use crate::spec::adapter::{make_policy, SlPolicy};
use crate::spec::cap;

/// The speculative-decoding serving engine.
pub struct Engine {
    pub cfg: EngineConfig,
    model: Box<dyn SpecModel>,
    policy: Box<dyn SlPolicy>,
    scheduler: Scheduler,
    kv: KvCache,
    waiting: VecDeque<SeqState>,
    running: Vec<SeqState>,
    finished: Vec<FinishedRequest>,
    pub metrics: EngineMetrics,
    clock: f64,
    real_t0: Instant,
    uses_virtual_time: bool,
}

impl Engine {
    pub fn new(cfg: EngineConfig, model: Box<dyn SpecModel>) -> Engine {
        let policy = make_policy(&cfg.policy);
        Engine::with_policy(cfg, model, policy)
    }

    /// Construct with an explicit policy object (ablation variants and
    /// custom adapters that have no [`crate::config::SlPolicyKind`] tag).
    pub fn with_policy(
        cfg: EngineConfig,
        model: Box<dyn SpecModel>,
        policy: Box<dyn SlPolicy>,
    ) -> Engine {
        cfg.validate().expect("invalid engine config");
        let scheduler = Scheduler::new(cfg.max_batch);
        let kv = KvCache::new(cfg.kv_blocks, cfg.kv_block_size);
        Engine {
            scheduler,
            kv,
            policy,
            model,
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            metrics: EngineMetrics::default(),
            clock: 0.0,
            real_t0: Instant::now(),
            uses_virtual_time: false,
        }
    }

    /// Current engine time (virtual or wall).
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Queue a request.
    pub fn submit(&mut self, mut req: Request) {
        req.arrival = self.clock;
        self.waiting.push_back(SeqState::from_request(req));
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    /// Drive until all submitted requests complete; returns them.
    pub fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        while self.pending() > 0 {
            if !self.step().expect("engine step failed") {
                break;
            }
        }
        self.take_finished()
    }

    /// One engine step.  Returns false when there was nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        self.metrics.steps += 1;
        self.scheduler
            .admit(&mut self.waiting, &mut self.running, &mut self.kv);
        if self.running.is_empty() {
            return Ok(false);
        }

        // ---- SL assignment (adapter -> budget clamps -> batch cap) ----------
        let max_len = self.model.max_len().min(self.cfg.max_len);
        let spec_k = self.model.spec_k().min(self.cfg.spec_k);
        let mut sls: Vec<usize> = if self.cfg.speculative {
            self.running
                .iter()
                .map(|s| {
                    let want = self.policy.propose(&s.signals).clamp(1, spec_k);
                    let ctx_room = max_len.saturating_sub(s.tokens.len() + 1);
                    let budget = s.remaining().max(1);
                    want.min(ctx_room.max(1)).min(budget)
                })
                .collect()
        } else {
            vec![0; self.running.len()]
        };
        let max_sl_pre_cap = sls.iter().copied().max().unwrap_or(0);
        if self.cfg.speculative {
            cap::apply_cap(self.cfg.cap_mode, &mut sls);
        }

        // ---- KV look-ahead pre-mapping (may preempt) -------------------------
        let outcome = self.scheduler.reserve_lookahead(
            &mut self.running,
            &mut sls,
            &mut self.kv,
            &mut self.waiting,
        );
        debug_assert!(self.kv.check_invariants().is_ok());
        if self.running.is_empty() {
            return Ok(!self.waiting.is_empty());
        }
        let _ = outcome;

        // ---- model round ------------------------------------------------------
        let round = {
            let running = &self.running;
            let policy = &self.policy;
            let inputs: Vec<SeqInput<'_>> = running
                .iter()
                .map(|s| SeqInput {
                    id: s.id,
                    tokens: &s.tokens,
                    temperature: if s.params.temperature != 0.0 {
                        s.params.temperature
                    } else {
                        self.cfg.temperature
                    },
                })
                .collect();
            let stop = |i: usize, j: usize, ent: f32, top_p: f32| -> bool {
                policy.should_stop(&running[i].signals, j, ent, top_p)
            };
            if self.cfg.speculative {
                self.model.spec_round(&inputs, &sls, &stop)?
            } else {
                self.model.ar_round(&inputs)?
            }
        };
        debug_assert!(round.validate(self.running.len()).is_ok());

        // ---- clock -----------------------------------------------------------
        match round.sim_cost {
            Some(c) => {
                self.uses_virtual_time = true;
                self.clock += c;
                self.metrics.busy_time += c;
            }
            None => {
                let t = self.real_t0.elapsed().as_secs_f64();
                self.metrics.busy_time += t - self.clock;
                self.clock = t;
            }
        }
        self.metrics.now = self.clock;

        // ---- apply outcome ----------------------------------------------------
        if self.cfg.speculative {
            self.metrics.verify_rounds += 1;
        } else {
            self.metrics.ar_rounds += 1;
        }
        let max_drafted = round.drafted.iter().copied().max().unwrap_or(0);
        self.metrics.seq_rounds += self.running.len() as u64;
        self.metrics.batch_hist.push(self.running.len() as f64);
        self.metrics.sl_hist.push(max_drafted as f64);
        let _ = max_sl_pre_cap;
        let calib_steps = self.policy.calibration_steps();
        for (i, seq) in self.running.iter_mut().enumerate() {
            let new_tokens = &round.new_tokens[i];
            if seq.first_token_at.is_none() && !new_tokens.is_empty() {
                seq.first_token_at = Some(self.clock);
            }
            // budget clamp: never emit beyond max_tokens
            let take = new_tokens.len().min(seq.remaining());
            seq.tokens.extend_from_slice(&new_tokens[..take]);
            seq.rounds += 1;
            self.metrics.tokens_out += take as u64;
            self.metrics.drafted += round.drafted[i] as u64;
            self.metrics.accepted += round.accepted[i] as u64;
            self.metrics.straggler_bubble +=
                (max_drafted - round.drafted[i]) as u64;
            // signals: calibration phase first (paper §3.1.1), then normal
            let calibrating = self.policy.wants_calibration()
                && seq.signals.calibrated_sl_max.is_none();
            if calibrating {
                seq.signals
                    .record_calibration(&round.klds[i], round.accepted[i]);
            }
            seq.signals.record_step(
                &round.klds[i],
                &round.entropies[i],
                round.drafted[i],
                round.accepted[i],
            );
            if calibrating && seq.signals.steps >= calib_steps {
                self.policy.finish_calibration(&mut seq.signals);
            }
            // reallocation: reclaim over-mapped look-ahead blocks
            self.kv.trim(seq.id, seq.tokens.len());
        }

        // ---- retire finished sequences -----------------------------------------
        let mut i = 0;
        while i < self.running.len() {
            if let Some(reason) = self.running[i].is_done(max_len) {
                let seq = self.running.remove(i);
                self.retire(seq, reason);
            } else {
                i += 1;
            }
        }
        Ok(true)
    }

    fn retire(&mut self, seq: SeqState, reason: FinishReason) {
        self.kv.release(seq.id);
        self.model.release(seq.id);
        let fin = FinishedRequest {
            id: seq.id,
            output: seq.tokens[seq.prompt_len..].to_vec(),
            reason,
            arrival: seq.arrival,
            finished_at: self.clock,
            first_token_at: seq.first_token_at.unwrap_or(self.clock),
            rounds: seq.rounds,
            drafted: seq.signals.drafted_total,
            accepted: seq.signals.accepted_total,
            preemptions: seq.preemptions,
        };
        self.metrics.requests.push(RequestMetrics {
            id: fin.id,
            latency: fin.latency(),
            ttft: fin.ttft(),
            output_tokens: fin.output.len(),
            rounds: fin.rounds,
            drafted: fin.drafted,
            accepted: fin.accepted,
            preemptions: fin.preemptions,
        });
        self.finished.push(fin);
    }

    /// Abort all in-flight work (server shutdown).
    pub fn abort_all(&mut self) {
        let drained: Vec<SeqState> = self
            .running
            .drain(..)
            .chain(self.waiting.drain(..))
            .collect();
        for seq in drained {
            self.retire(seq, FinishReason::Aborted);
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn model_name(&self) -> String {
        self.model.name()
    }

    pub fn kv_used_blocks(&self) -> usize {
        self.kv.used_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SlPolicyKind;
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engine(policy: SlPolicyKind, speculative: bool) -> Engine {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 512,
            speculative,
            policy,
            seed: 7,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 7)
            .with_max_len(512);
        Engine::new(cfg, Box::new(model))
    }

    fn submit_n(e: &mut Engine, n: usize, max_tokens: usize) {
        for i in 0..n {
            e.submit(Request::new(
                i as u64,
                vec![65; 32],
                crate::engine::request::SamplingParams {
                    max_tokens,
                    ..Default::default()
                },
            ));
        }
    }

    #[test]
    fn completes_all_requests() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 6, 40);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 6);
        for r in &done {
            assert_eq!(r.output.len(), 40);
            assert_eq!(r.reason, FinishReason::MaxTokens);
            assert!(r.latency() > 0.0);
        }
    }

    #[test]
    fn autoregressive_mode_works() {
        let mut e = sim_engine(SlPolicyKind::Static(4), false);
        submit_n(&mut e, 2, 16);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 2);
        assert_eq!(e.metrics.ar_rounds, 16); // one token per round per seq
        assert_eq!(e.metrics.drafted, 0);
    }

    #[test]
    fn speculative_beats_autoregressive_on_virtual_time() {
        let mut ar = sim_engine(SlPolicyKind::Static(4), false);
        submit_n(&mut ar, 4, 64);
        ar.run_to_completion();
        let mut sp = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut sp, 4, 64);
        sp.run_to_completion();
        assert!(
            sp.metrics.mean_latency() < 0.7 * ar.metrics.mean_latency(),
            "spec {} vs ar {}",
            sp.metrics.mean_latency(),
            ar.metrics.mean_latency()
        );
    }

    #[test]
    fn dsde_policy_runs_and_calibrates() {
        let mut e = sim_engine(
            SlPolicyKind::Dsde(crate::spec::adapter::DsdeConfig::default()),
            true,
        );
        submit_n(&mut e, 3, 48);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 3);
        assert!(e.metrics.block_efficiency() > 1.0);
    }

    #[test]
    fn adaedl_policy_runs() {
        let mut e = sim_engine(
            SlPolicyKind::AdaEdl(crate::spec::adapter::AdaEdlConfig::default()),
            true,
        );
        submit_n(&mut e, 3, 32);
        assert_eq!(e.run_to_completion().len(), 3);
    }

    #[test]
    fn block_efficiency_reasonable_for_high_acceptance() {
        let mut e = sim_engine(SlPolicyKind::Static(8), true);
        submit_n(&mut e, 4, 96);
        e.run_to_completion();
        let be = e.metrics.block_efficiency();
        assert!(be > 2.0 && be < 7.0, "BE {be}");
    }

    #[test]
    fn kv_pressure_causes_preemption_but_everything_finishes() {
        let cfg = EngineConfig {
            max_batch: 8,
            max_len: 512,
            kv_blocks: 24, // tight: 24*16 = 384 token slots for 8 seqs
            speculative: true,
            policy: SlPolicyKind::Static(6),
            seed: 3,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 3)
            .with_max_len(512);
        let mut e = Engine::new(cfg, Box::new(model));
        submit_n(&mut e, 8, 48);
        let done = e.run_to_completion();
        assert_eq!(done.len(), 8);
        let preempted: usize = done.iter().map(|r| r.preemptions).sum();
        assert!(preempted > 0, "expected KV preemptions under pressure");
    }

    #[test]
    fn max_tokens_never_exceeded() {
        let mut e = sim_engine(SlPolicyKind::Static(8), true);
        submit_n(&mut e, 5, 10);
        let done = e.run_to_completion();
        for r in &done {
            assert!(r.output.len() <= 10);
        }
    }

    #[test]
    fn virtual_clock_advances() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 1, 16);
        e.run_to_completion();
        assert!(e.now() > 0.0);
        assert!(e.metrics.busy_time > 0.0);
    }

    #[test]
    fn straggler_bubble_tracked_without_cap() {
        let cfg = EngineConfig {
            max_batch: 8,
            max_len: 512,
            speculative: true,
            policy: SlPolicyKind::Dsde(Default::default()),
            cap_mode: crate::config::CapMode::None,
            seed: 11,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::sharegpt(), 11)
            .with_max_len(512);
        let mut e = Engine::new(cfg, Box::new(model));
        submit_n(&mut e, 8, 64);
        e.run_to_completion();
        assert!(e.metrics.straggler_bubble > 0);
    }

    #[test]
    fn abort_drains_everything() {
        let mut e = sim_engine(SlPolicyKind::Static(4), true);
        submit_n(&mut e, 4, 1000);
        e.step().unwrap();
        e.abort_all();
        assert_eq!(e.pending(), 0);
        let done = e.take_finished();
        assert_eq!(done.len(), 4);
        assert!(done.iter().any(|r| r.reason == FinishReason::Aborted));
    }
}
