//! The staged step pipeline: `plan → execute → apply`.
//!
//! One engine step used to be a single ~140-line monolith; it is now three
//! independently testable stages with typed boundaries:
//!
//! * [`Engine::plan`] — admission, SL assignment (adapter proposal → budget
//!   clamps → batch-wide cap, paper §3.3), and KV look-ahead pre-mapping
//!   (which may preempt).  Produces a [`StepPlan`] — or reports that there
//!   is nothing runnable.
//! * [`Engine::execute`] — the model round (speculative draft + ragged
//!   verify + rejection sampling, or one autoregressive token each) for the
//!   planned batch.  Pure with respect to scheduling state.
//! * [`Engine::apply`] — clock advance, token/signal application, adapter
//!   calibration bookkeeping, KV trim, round-metric accounting, and
//!   retirement.  Produces a [`StepReport`].  (Scheduler-outcome counters
//!   are recorded by `plan` at decision time so they survive an
//!   `execute` failure.)
//!
//! [`Engine::step`] (in [`super::engine`]) is the thin driver chaining the
//! three.  Callers that want per-step introspection (benches, the router's
//! drain loop, tests) can drive the stages directly.

use anyhow::Result;

use super::engine::{Engine, ReplicaLoad};
use crate::model::traits::{RoundOutcome, SeqInput};
use crate::spec::cap;

/// What the planner decided for this step.
#[derive(Debug)]
pub enum PlanOutcome {
    /// Nothing runnable and nothing that can become runnable on its own —
    /// the step loop should stop driving.
    Idle,
    /// Nothing runnable *this* step, but queued work may proceed on a
    /// later one (e.g. every running sequence was preempted back to the
    /// waiting queue).
    Retry,
    /// A scheduled batch ready for [`Engine::execute`].
    Run(StepPlan),
}

/// The typed output of the planning stage: everything the execute/apply
/// stages need to know about scheduling decisions, decoupled from clock and
/// metric bookkeeping.
#[derive(Clone, Debug)]
pub struct StepPlan {
    /// Scheduled batch size (length of the running list at plan time).
    pub batch: usize,
    /// Granted speculation length per running sequence (post cap and KV
    /// reservation; all zeros in autoregressive mode).
    pub sls: Vec<usize>,
    /// Whether this round runs speculative decoding.
    pub speculative: bool,
    /// Effective context capacity for retirement checks.
    pub max_len: usize,
    /// Maximum proposed SL before the batch-wide cap was applied.
    pub max_sl_pre_cap: usize,
    /// Draft slots the cap shaved off the round critical path:
    /// `max_sl_pre_cap - max(sls after cap)` (paper §3.3 ablation signal).
    pub cap_savings: usize,
    /// Sequences admitted from the waiting queue this step.
    pub admitted: usize,
    /// Sequence ids preempted back to the waiting queue this step.
    pub preempted: Vec<u64>,
}

/// One request's accepted-token delta from a single engine step — the unit
/// of incremental output forwarded to streaming consumers (the replica loop
/// fans these out to per-request channels; see
/// [`crate::server::router::EngineRouter::submit_streaming`]).
#[derive(Clone, Debug)]
pub struct TokenDelta {
    /// Request id the tokens belong to.
    pub id: u64,
    /// Tokens appended this step (post budget clamp), in generation order.
    pub tokens: Vec<u32>,
    /// Engine-clock time the tokens were applied at.
    pub t: f64,
}

/// The typed output of the apply stage: what one executed step did.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// Batch size the round ran with.
    pub batch: usize,
    /// Whether this round ran speculative decoding.
    pub speculative: bool,
    /// Tokens appended across the batch this step (post budget clamp).
    pub tokens: usize,
    /// Draft tokens proposed this step.
    pub drafted: usize,
    /// Draft tokens accepted this step.
    pub accepted: usize,
    /// Sequences admitted this step (carried through from the plan).
    pub admitted: usize,
    /// Sequence ids preempted this step (carried through from the plan).
    pub preempted: Vec<u64>,
    /// Draft slots the batch-wide cap shaved (carried through from the plan).
    pub cap_savings: usize,
    /// Per-request accepted-token deltas, one entry per sequence that
    /// gained tokens this step — the streaming feed.
    pub deltas: Vec<TokenDelta>,
    /// Ids of sequences retired by this step.
    pub finished: Vec<u64>,
    /// Round cost on the engine clock (virtual or wall seconds).
    pub cost: f64,
    /// Post-step replica-load snapshot (KV occupancy + queue pressure) —
    /// what the serving layer publishes for KV-aware placement.
    pub load: ReplicaLoad,
}

impl Engine {
    /// Stage 1 — admission + SL assignment + batch cap + KV look-ahead
    /// reservation.  Mutates scheduling state (admits, preempts, pre-maps
    /// KV) and records the scheduler-outcome counters
    /// (`admitted`/`preemptions`/`cap_savings`) at decision time — so they
    /// stay exact even if [`Engine::execute`] later fails — but never
    /// touches the clock or round metrics (those belong to
    /// [`Engine::apply`]).
    pub fn plan(&mut self) -> PlanOutcome {
        // fleet-controller actuators, sampled once per step so admission
        // and SL capping see the same decision (None = no controller
        // attached: the entire control path below is a no-op)
        let ctrl = self.control.as_ref().map(|c| c.view());
        let admit_limit = match &ctrl {
            Some(v) => (((self.cfg.max_batch as f64) * v.admit_frac) as usize).max(1),
            None => usize::MAX,
        };
        let now = self.clock;
        let mut admitted = self.scheduler.admit_prioritized(
            &mut self.waiting,
            &mut self.running,
            &mut self.kv,
            admit_limit,
            now,
        );
        // tenancy pressure valve: a blocked interactive (or deadline-tight)
        // arrival evicts the most recently admitted best-effort sequence —
        // one per step, so a single hot tenant cannot flush the whole
        // batch.  Uniform-class traffic never takes this branch.
        let mut priority_preempted: Vec<u64> = Vec::new();
        let blocked_urgent = self.waiting.iter().any(|s| {
            s.class == crate::engine::request::PriorityClass::Interactive
                || s.deadline_slack_frac(now)
                    .is_some_and(|f| f < cap::TIGHT_SLACK_FRAC)
        });
        if blocked_urgent
            && self
                .running
                .iter()
                .any(|s| s.class == crate::engine::request::PriorityClass::BestEffort)
        {
            if let Some(id) = self.scheduler.preempt_best_effort(
                &mut self.running,
                &mut self.kv,
                &mut self.waiting,
            ) {
                priority_preempted.push(id);
                admitted += self.scheduler.admit_prioritized(
                    &mut self.waiting,
                    &mut self.running,
                    &mut self.kv,
                    admit_limit,
                    now,
                );
            }
        }
        if self.running.is_empty() {
            self.metrics.preemptions += priority_preempted.len() as u64;
            // a priority eviction can momentarily empty the batch; its
            // victim is back in the waiting queue and admissible next step
            if !priority_preempted.is_empty() && !self.waiting.is_empty() {
                return PlanOutcome::Retry;
            }
            // nothing admitted and nothing running: either drained, or the
            // head-of-line prompt can never fit (caller's capacity problem)
            return PlanOutcome::Idle;
        }

        // ---- SL assignment (adapter -> budget clamps -> batch cap) ------
        let max_len = self.model.max_len().min(self.cfg.max_len);
        let spec_k = self.model.spec_k().min(self.cfg.spec_k);
        let speculative = self.cfg.speculative;
        let mut sls: Vec<usize> = if speculative {
            self.running
                .iter()
                .map(|s| {
                    let want = self.policy.propose(&s.signals).clamp(1, spec_k);
                    let ctx_room = max_len.saturating_sub(s.tokens.len() + 1);
                    let budget = s.remaining().max(1);
                    want.min(ctx_room.max(1)).min(budget)
                })
                .collect()
        } else {
            vec![0; self.running.len()]
        };
        let max_sl_pre_cap = sls.iter().copied().max().unwrap_or(0);
        if speculative {
            cap::apply_cap(self.cfg.cap_mode, &mut sls);
            if let Some(view) = &ctrl {
                // controller throttle applies after the batch-consensus
                // cap, so its shavings land in cap_savings below
                cap::apply_control(view, &mut sls);
            }
        }
        let max_sl_post_cap = sls.iter().copied().max().unwrap_or(0);
        if speculative {
            // deadline-slack clamp after cap_savings accounting: deadline
            // conservatism is tracked separately (deadline_clamps), and a
            // batch with no deadlines is bit-identical either way
            let slack: Vec<Option<f64>> = self
                .running
                .iter()
                .map(|s| s.deadline_slack_frac(now))
                .collect();
            let clamped = cap::apply_deadline_slack(&mut sls, &slack);
            self.metrics.deadline_clamps += clamped as u64;
        }

        // ---- KV look-ahead pre-mapping (may preempt) --------------------
        let outcome = self.scheduler.reserve_lookahead(
            &mut self.running,
            &mut sls,
            &mut self.kv,
            &mut self.waiting,
        );
        debug_assert!(self.kv.check_invariants().is_ok());
        self.metrics.admitted += admitted as u64;
        self.metrics.preemptions +=
            (priority_preempted.len() + outcome.preempted.len()) as u64;
        if self.running.is_empty() {
            // the whole batch was preempted away; no round will run (and
            // no cap savings materialize)
            return if self.waiting.is_empty() {
                PlanOutcome::Idle
            } else {
                PlanOutcome::Retry
            };
        }
        let cap_savings = max_sl_pre_cap - max_sl_post_cap;
        self.metrics.cap_savings += cap_savings as u64;

        let mut preempted = priority_preempted;
        preempted.extend(outcome.preempted);
        PlanOutcome::Run(StepPlan {
            batch: self.running.len(),
            sls,
            speculative,
            max_len,
            max_sl_pre_cap,
            cap_savings,
            admitted,
            preempted,
        })
    }

    /// Stage 2 — run the model round for the planned batch.  Does not touch
    /// scheduling state, the clock, or metrics; failures surface here so
    /// the caller can retry or abort without corrupted bookkeeping.
    pub fn execute(&mut self, plan: &StepPlan) -> Result<RoundOutcome> {
        debug_assert_eq!(plan.batch, self.running.len());
        debug_assert_eq!(plan.sls.len(), self.running.len());
        let round = {
            let running = &self.running;
            let policy = &self.policy;
            let inputs: Vec<SeqInput<'_>> = running
                .iter()
                .map(|s| SeqInput {
                    id: s.id,
                    tokens: &s.tokens,
                    temperature: if s.params.temperature != 0.0 {
                        s.params.temperature
                    } else {
                        self.cfg.temperature
                    },
                })
                .collect();
            let stop = |i: usize, j: usize, ent: f32, top_p: f32| -> bool {
                policy.should_stop(&running[i].signals, j, ent, top_p)
            };
            if plan.speculative {
                self.model.spec_round(&inputs, &plan.sls, &stop)?
            } else {
                self.model.ar_round(&inputs)?
            }
        };
        debug_assert!(round.validate(self.running.len()).is_ok());
        Ok(round)
    }

    /// Stage 3 — advance the clock, apply tokens and adapter signals,
    /// account round metrics, trim over-mapped KV, and retire finished
    /// sequences.  (Scheduler-outcome counters were already recorded by
    /// [`Engine::plan`].)
    pub fn apply(&mut self, plan: StepPlan, round: RoundOutcome) -> StepReport {
        // ---- clock ------------------------------------------------------
        let cost = match round.sim_cost {
            Some(c) => {
                self.uses_virtual_time = true;
                self.clock += c;
                self.metrics.busy_time += c;
                c
            }
            None => {
                let t = self.real_t0.elapsed().as_secs_f64();
                let delta = t - self.clock;
                self.metrics.busy_time += delta;
                self.clock = t;
                delta
            }
        };
        self.metrics.now = self.clock;

        // ---- step-level counters ---------------------------------------
        if plan.speculative {
            self.metrics.verify_rounds += 1;
        } else {
            self.metrics.ar_rounds += 1;
        }
        // (admitted/preemptions/cap_savings were recorded by plan() at
        // decision time; the plan carries copies for the report only)
        let max_drafted = round.drafted.iter().copied().max().unwrap_or(0);
        self.metrics.seq_rounds += self.running.len() as u64;
        self.metrics.batch_hist.push(self.running.len() as f64);
        self.metrics.sl_hist.push(max_drafted as f64);

        // ---- per-sequence application -----------------------------------
        let calib_steps = self.policy.calibration_steps();
        let mut tokens = 0usize;
        let mut drafted = 0usize;
        let mut accepted = 0usize;
        let mut deltas: Vec<TokenDelta> = Vec::new();
        for (i, seq) in self.running.iter_mut().enumerate() {
            let new_tokens = &round.new_tokens[i];
            if seq.first_token_at.is_none() && !new_tokens.is_empty() {
                seq.first_token_at = Some(self.clock);
            }
            // budget clamp: never emit beyond max_tokens
            let take = new_tokens.len().min(seq.remaining());
            seq.tokens.extend_from_slice(&new_tokens[..take]);
            if take > 0 {
                deltas.push(TokenDelta {
                    id: seq.id,
                    tokens: new_tokens[..take].to_vec(),
                    t: self.clock,
                });
            }
            seq.rounds += 1;
            tokens += take;
            drafted += round.drafted[i];
            accepted += round.accepted[i];
            self.metrics.record_class_sl(seq.class, plan.sls[i]);
            self.metrics.tokens_out += take as u64;
            self.metrics.drafted += round.drafted[i] as u64;
            self.metrics.accepted += round.accepted[i] as u64;
            self.metrics.straggler_bubble +=
                (max_drafted - round.drafted[i]) as u64;
            // signals: calibration phase first (paper §3.1.1), then normal
            let calibrating = self.policy.wants_calibration()
                && seq.signals.calibrated_sl_max.is_none();
            if calibrating {
                seq.signals
                    .record_calibration(&round.klds[i], round.accepted[i]);
            }
            seq.signals.record_step(
                &round.klds[i],
                &round.entropies[i],
                round.drafted[i],
                round.accepted[i],
            );
            if calibrating && seq.signals.steps >= calib_steps {
                self.policy.finish_calibration(&mut seq.signals);
            }
            // reallocation: reclaim over-mapped look-ahead blocks
            self.kv.trim(seq.id, seq.tokens.len());
        }

        // ---- retire finished sequences ----------------------------------
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if let Some(reason) = self.running[i].is_done(plan.max_len) {
                let seq = self.running.remove(i);
                finished.push(seq.id);
                self.retire(seq, reason);
            } else {
                i += 1;
            }
        }

        StepReport {
            batch: plan.batch,
            speculative: plan.speculative,
            tokens,
            drafted,
            accepted,
            admitted: plan.admitted,
            preempted: plan.preempted,
            cap_savings: plan.cap_savings,
            deltas,
            finished,
            cost,
            load: self.load_snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CapMode, EngineConfig, SlPolicyKind};
    use crate::engine::request::{Request, SamplingParams};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;
    use crate::spec::adapter::DsdeConfig;

    fn engine(cfg: EngineConfig) -> Engine {
        let seed = cfg.seed;
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), seed)
            .with_max_len(cfg.max_len);
        Engine::new(cfg, Box::new(model))
    }

    fn default_engine() -> Engine {
        engine(EngineConfig {
            max_batch: 4,
            max_len: 512,
            policy: SlPolicyKind::Static(4),
            seed: 9,
            ..Default::default()
        })
    }

    fn submit_n(e: &mut Engine, n: usize, max_tokens: usize) {
        for i in 0..n {
            e.submit(Request::new(
                i as u64,
                vec![65; 32],
                SamplingParams {
                    max_tokens,
                    ..Default::default()
                },
            ));
        }
    }

    // ---- plan -----------------------------------------------------------

    #[test]
    fn plan_idle_with_no_work() {
        let mut e = default_engine();
        assert!(matches!(e.plan(), PlanOutcome::Idle));
    }

    #[test]
    fn plan_grants_bounded_sls_and_admits() {
        let mut e = default_engine();
        submit_n(&mut e, 6, 32);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.batch, 4); // max_batch bound
        assert_eq!(plan.admitted, 4);
        assert_eq!(plan.sls.len(), 4);
        assert!(plan.speculative);
        assert!(plan.sls.iter().all(|&sl| (1..=4).contains(&sl)));
        assert_eq!(plan.max_sl_pre_cap, 4);
        assert!(plan.preempted.is_empty());
    }

    #[test]
    fn plan_autoregressive_grants_zero_sls() {
        let mut e = engine(EngineConfig {
            max_batch: 4,
            max_len: 512,
            speculative: false,
            policy: SlPolicyKind::Static(4),
            seed: 9,
            ..Default::default()
        });
        submit_n(&mut e, 2, 8);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert!(!plan.speculative);
        assert_eq!(plan.sls, vec![0, 0]);
        assert_eq!(plan.cap_savings, 0);
    }

    #[test]
    fn plan_respects_output_budget() {
        let mut e = default_engine();
        submit_n(&mut e, 1, 2); // only 2 tokens wanted => SL clamped to <= 2
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert!(plan.sls[0] <= 2, "sls {:?}", plan.sls);
    }

    #[test]
    fn plan_under_kv_pressure_records_preemption() {
        // prompts of 45 tokens: admission maps 3 blocks each (47 slots);
        // the SL-6 look-ahead needs a 4th block each, and with 10 blocks
        // total only two sequences can grow — the tail is preempted.
        let mut e = engine(EngineConfig {
            max_batch: 8,
            max_len: 512,
            kv_blocks: 10,
            policy: SlPolicyKind::Static(6),
            seed: 3,
            ..Default::default()
        });
        for i in 0..8 {
            e.submit(Request::new(
                i as u64,
                vec![65; 45],
                SamplingParams {
                    max_tokens: 48,
                    ..Default::default()
                },
            ));
        }
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.admitted, 3);
        assert!(
            !plan.preempted.is_empty(),
            "tight KV must preempt the tail: {plan:?}"
        );
        assert_eq!(plan.batch, plan.sls.len());
    }

    #[test]
    fn plan_honors_control_actuators() {
        use crate::spec::control::ControlCell;
        use std::sync::Arc;
        let mut e = default_engine();
        let cell = Arc::new(ControlCell::new());
        cell.store(1, 0.5, 1.0); // SL cap 1, admit half the batch
        e.set_control(cell);
        submit_n(&mut e, 6, 32);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.batch, 2, "admission gated to max_batch/2");
        assert!(plan.sls.iter().all(|&sl| sl == 1), "sls {:?}", plan.sls);
        assert_eq!(
            plan.cap_savings,
            plan.max_sl_pre_cap - 1,
            "control shavings are accounted as cap savings"
        );
    }

    #[test]
    fn neutral_control_cell_plans_identically() {
        use crate::spec::control::ControlCell;
        use std::sync::Arc;
        let mut plain = default_engine();
        let mut ctl = default_engine();
        ctl.set_control(Arc::new(ControlCell::new()));
        submit_n(&mut plain, 6, 32);
        submit_n(&mut ctl, 6, 32);
        let (PlanOutcome::Run(a), PlanOutcome::Run(b)) = (plain.plan(), ctl.plan())
        else {
            panic!("expected runnable plans")
        };
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.sls, b.sls);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.cap_savings, b.cap_savings);
    }

    #[test]
    fn plan_preempts_best_effort_for_blocked_interactive() {
        use crate::engine::request::PriorityClass;
        let mut e = engine(EngineConfig {
            max_batch: 2,
            max_len: 512,
            policy: SlPolicyKind::Static(4),
            seed: 9,
            ..Default::default()
        });
        for i in 0..2 {
            e.submit(
                Request::new(i, vec![65; 32], Default::default()).with_tenancy(
                    "batch",
                    PriorityClass::BestEffort,
                    None,
                ),
            );
        }
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.batch, 2);
        assert!(plan.preempted.is_empty());
        // a blocked interactive arrival evicts the youngest best-effort
        e.submit(
            Request::new(7, vec![65; 32], Default::default()).with_tenancy(
                "chat",
                PriorityClass::Interactive,
                None,
            ),
        );
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.preempted, vec![1], "tail best-effort evicted");
        assert!(e.running.iter().any(|s| s.id == 7), "interactive admitted");
        assert!(
            e.waiting.iter().any(|s| s.id == 1 && s.preemptions == 1),
            "victim re-queued with its preemption counted"
        );
        // one eviction per step: the surviving best-effort keeps running
        assert!(e.running.iter().any(|s| s.id == 0));
        // and everything still completes
        let done = e.run_to_completion();
        assert_eq!(done.len(), 3);
    }

    #[test]
    fn plan_clamps_sl_for_tight_deadlines() {
        let mut e = default_engine(); // Static(4)
        // slack request: full deadline budget remains
        let slackful = Request::new(0, vec![65; 32], SamplingParams {
            max_tokens: 32,
            ..Default::default()
        })
        .with_tenancy("a", Default::default(), Some(10_000));
        e.submit(slackful);
        // tight request: 92% of its 1 s deadline already spent queueing
        let mut tight = Request::new(1, vec![65; 32], SamplingParams {
            max_tokens: 32,
            ..Default::default()
        })
        .with_tenancy("b", Default::default(), Some(1_000));
        tight.waited = 0.92;
        e.submit(tight);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        assert_eq!(plan.sls[1], 1, "critical slack clamps to SL 1: {:?}", plan.sls);
        assert!(plan.sls[0] > 1, "slack request keeps its SL: {:?}", plan.sls);
        assert_eq!(e.metrics.deadline_clamps, 1);
    }

    #[test]
    fn tenant_attribution_alone_plans_identically() {
        let mut plain = default_engine();
        let mut tagged = default_engine();
        submit_n(&mut plain, 4, 32);
        for i in 0..4 {
            tagged.submit(
                Request::new(i as u64, vec![65; 32], SamplingParams {
                    max_tokens: 32,
                    ..Default::default()
                })
                .with_tenancy("acme", Default::default(), None),
            );
        }
        let (PlanOutcome::Run(a), PlanOutcome::Run(b)) = (plain.plan(), tagged.plan())
        else {
            panic!("expected runnable plans")
        };
        assert_eq!(a.sls, b.sls);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.preempted, b.preempted);
        assert_eq!(plain.metrics.deadline_clamps, 0);
        assert_eq!(tagged.metrics.deadline_clamps, 0);
    }

    // ---- execute --------------------------------------------------------

    #[test]
    fn execute_round_is_consistent_with_plan() {
        let mut e = default_engine();
        submit_n(&mut e, 3, 32);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let round = e.execute(&plan).unwrap();
        assert!(round.validate(plan.batch).is_ok());
        for i in 0..plan.batch {
            assert!(round.drafted[i] <= plan.sls[i]);
            assert_eq!(round.new_tokens[i].len(), round.accepted[i] + 1);
        }
        assert!(round.sim_cost.unwrap() > 0.0);
    }

    #[test]
    fn execute_does_not_touch_clock_or_metrics() {
        let mut e = default_engine();
        submit_n(&mut e, 2, 16);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let before_now = e.now();
        let before_tokens = e.metrics.tokens_out;
        let _ = e.execute(&plan).unwrap();
        assert_eq!(e.now(), before_now);
        assert_eq!(e.metrics.tokens_out, before_tokens);
    }

    // ---- apply ----------------------------------------------------------

    #[test]
    fn apply_extends_sequences_and_advances_clock() {
        let mut e = default_engine();
        submit_n(&mut e, 2, 16);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let round = e.execute(&plan).unwrap();
        let report = e.apply(plan, round);
        assert_eq!(report.batch, 2);
        assert!(report.tokens > 0);
        assert!(report.cost > 0.0);
        assert_eq!(report.admitted, 2);
        assert!(e.now() > 0.0);
        assert_eq!(e.metrics.tokens_out, report.tokens as u64);
        assert_eq!(e.metrics.admitted, 2);
    }

    #[test]
    fn apply_retires_on_budget_exhaustion() {
        let mut e = default_engine();
        submit_n(&mut e, 1, 1); // one token and done
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let round = e.execute(&plan).unwrap();
        let report = e.apply(plan, round);
        assert_eq!(report.finished, vec![0]);
        assert_eq!(report.tokens, 1);
        assert_eq!(e.pending(), 0);
        assert_eq!(e.take_finished().len(), 1);
    }

    #[test]
    fn apply_reports_per_request_deltas() {
        let mut e = default_engine();
        submit_n(&mut e, 2, 16);
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let round = e.execute(&plan).unwrap();
        let report = e.apply(plan, round);
        assert!(!report.deltas.is_empty());
        // the deltas partition the step's emitted tokens by request
        let delta_total: usize = report.deltas.iter().map(|d| d.tokens.len()).sum();
        assert_eq!(delta_total, report.tokens);
        let mut ids: Vec<u64> = report.deltas.iter().map(|d| d.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), report.deltas.len(), "one delta per request");
        for d in &report.deltas {
            assert!((d.t - e.now()).abs() < 1e-12, "stamped at the round clock");
            assert!(!d.tokens.is_empty());
        }
    }

    #[test]
    fn report_load_snapshot_matches_engine_state() {
        let mut e = default_engine();
        submit_n(&mut e, 6, 16); // max_batch 4: two stay queued
        let PlanOutcome::Run(plan) = e.plan() else {
            panic!("expected runnable plan")
        };
        let round = e.execute(&plan).unwrap();
        let report = e.apply(plan, round);
        assert_eq!(report.load, e.load_snapshot());
        assert_eq!(report.load.in_flight, 4);
        assert_eq!(report.load.queued_requests, 2);
        assert!(report.load.kv_used_blocks > 0);
        assert_eq!(
            report.load.kv_used_blocks + report.load.kv_free_blocks,
            e.cfg.kv_blocks
        );
    }

    #[test]
    fn staged_loop_matches_run_to_completion_totals() {
        // drive the stages manually and check the composition invariant:
        // emitted tokens across reports == engine tokens_out == outputs
        let mut e = default_engine();
        submit_n(&mut e, 5, 24);
        let mut total_tokens = 0usize;
        let mut total_finished = 0usize;
        loop {
            e.metrics.steps += 1;
            match e.plan() {
                PlanOutcome::Idle => break,
                PlanOutcome::Retry => continue,
                PlanOutcome::Run(plan) => {
                    let round = e.execute(&plan).unwrap();
                    let report = e.apply(plan, round);
                    total_tokens += report.tokens;
                    total_finished += report.finished.len();
                }
            }
        }
        assert_eq!(total_finished, 5);
        assert_eq!(total_tokens as u64, e.metrics.tokens_out);
        assert_eq!(e.take_finished().len(), 5);
        assert_eq!(e.metrics.tokens_out, 5 * 24);
    }

    #[test]
    fn cap_savings_accumulate_with_heterogeneous_proposals() {
        // DSDE proposals diverge across sequences after calibration, so the
        // mean cap must shave the max proposal in at least one round
        let mut e = {
            let cfg = EngineConfig {
                max_batch: 8,
                max_len: 512,
                policy: SlPolicyKind::Dsde(DsdeConfig::default()),
                cap_mode: CapMode::Mean,
                seed: 11,
                ..Default::default()
            };
            let model =
                SimModel::new(SimPairKind::LlamaLike, DatasetProfile::sharegpt(), 11)
                    .with_max_len(512);
            Engine::new(cfg, Box::new(model))
        };
        submit_n(&mut e, 8, 96);
        e.run_to_completion();
        assert!(
            e.metrics.cap_savings > 0,
            "mean cap should shave heterogeneous SL proposals"
        );
    }
}
