//! Paged KV-block manager (vLLM's PagedAttention block tables, §3 of the
//! paper: "the SL Adapter ... modifies the Look-ahead Scheduler to perform
//! pre-mapping and reallocation of KV memory blocks").
//!
//! Blocks are fixed-size token pages.  The scheduler *pre-maps* look-ahead
//! slots for the speculative tokens of the next round (`ctx + SL_i + 1`
//! incl. the bonus slot) before launching it; rejected-token slots are
//! reclaimed lazily when the sequence's real length is appended.  On
//! allocation failure the engine preempts (frees a victim's blocks and
//! requeues it).

use std::collections::HashMap;

/// Allocation failure: not enough free blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Oom {
    /// Blocks the allocation needed.
    pub requested: usize,
    /// Blocks that were free.
    pub free: usize,
}

/// Paged KV manager.
#[derive(Clone, Debug)]
pub struct KvCache {
    block_size: usize,
    total_blocks: usize,
    free: Vec<u32>,
    /// seq id -> block table (ordered)
    tables: HashMap<u64, Vec<u32>>,
}

impl KvCache {
    /// Construct a manager with `total_blocks` pages of `block_size` tokens.
    pub fn new(total_blocks: usize, block_size: usize) -> KvCache {
        assert!(block_size > 0 && total_blocks > 0);
        KvCache {
            block_size,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            tables: HashMap::new(),
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total blocks managed (free + allocated).
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently unallocated.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently mapped to sequences.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Current block table of a sequence (empty slice if unknown).
    pub fn table(&self, id: u64) -> &[u32] {
        self.tables.get(&id).map(|t| t.as_slice()).unwrap_or(&[])
    }

    /// Ensure the sequence can hold `tokens` tokens (pre-mapping).  Grows
    /// the block table as needed; never shrinks (see [`KvCache::trim`]).
    pub fn ensure(&mut self, id: u64, tokens: usize) -> Result<(), Oom> {
        let need = self.blocks_for(tokens);
        let have = self.tables.get(&id).map(|t| t.len()).unwrap_or(0);
        if need <= have {
            return Ok(());
        }
        let grow = need - have;
        if grow > self.free.len() {
            return Err(Oom {
                requested: grow,
                free: self.free.len(),
            });
        }
        let table = self.tables.entry(id).or_default();
        for _ in 0..grow {
            table.push(self.free.pop().unwrap());
        }
        Ok(())
    }

    /// Reallocation after verification: shrink the table to the sequence's
    /// real token count, returning over-mapped look-ahead blocks (the
    /// "ragged KV" reclaim — rejected speculative slots).
    pub fn trim(&mut self, id: u64, tokens: usize) {
        let need = self.blocks_for(tokens);
        if let Some(table) = self.tables.get_mut(&id) {
            while table.len() > need {
                self.free.push(table.pop().unwrap());
            }
        }
    }

    /// Release all blocks of a sequence (finish / preemption).
    pub fn release(&mut self, id: u64) {
        if let Some(table) = self.tables.remove(&id) {
            self.free.extend(table);
        }
    }

    /// Internal invariant: every block is either free or in exactly one
    /// table.  Exposed for tests/debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            let b = b as usize;
            if b >= self.total_blocks {
                return Err(format!("free block {b} out of range"));
            }
            if seen[b] {
                return Err(format!("block {b} double-listed"));
            }
            seen[b] = true;
        }
        for (id, table) in &self.tables {
            for &b in table {
                let b = b as usize;
                if b >= self.total_blocks {
                    return Err(format!("seq {id} block {b} out of range"));
                }
                if seen[b] {
                    return Err(format!("block {b} in seq {id} double-allocated"));
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked block (neither free nor allocated)".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};

    #[test]
    fn ensure_allocates_and_is_idempotent() {
        let mut kv = KvCache::new(10, 16);
        kv.ensure(1, 20).unwrap(); // 2 blocks
        assert_eq!(kv.table(1).len(), 2);
        assert_eq!(kv.free_blocks(), 8);
        kv.ensure(1, 20).unwrap(); // no-op
        assert_eq!(kv.free_blocks(), 8);
        kv.ensure(1, 33).unwrap(); // 3 blocks
        assert_eq!(kv.table(1).len(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn oom_reported_and_state_unchanged() {
        let mut kv = KvCache::new(2, 16);
        kv.ensure(1, 32).unwrap();
        let err = kv.ensure(2, 16).unwrap_err();
        assert_eq!(err, Oom { requested: 1, free: 0 });
        assert_eq!(kv.table(2).len(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn trim_reclaims_lookahead() {
        let mut kv = KvCache::new(8, 4);
        kv.ensure(7, 20).unwrap(); // 5 blocks pre-mapped (ctx+SL)
        assert_eq!(kv.used_blocks(), 5);
        kv.trim(7, 9); // only 9 tokens materialized -> 3 blocks
        assert_eq!(kv.used_blocks(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_everything() {
        let mut kv = KvCache::new(4, 8);
        kv.ensure(1, 30).unwrap();
        kv.release(1);
        assert_eq!(kv.free_blocks(), 4);
        assert_eq!(kv.table(1).len(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn blocks_for_rounds_up() {
        let kv = KvCache::new(4, 16);
        assert_eq!(kv.blocks_for(0), 0);
        assert_eq!(kv.blocks_for(1), 1);
        assert_eq!(kv.blocks_for(16), 1);
        assert_eq!(kv.blocks_for(17), 2);
    }

    /// Property: under random ensure/trim/release traffic, no block ever
    /// leaks or double-allocates, and capacity accounting stays exact.
    #[test]
    fn accounting_never_leaks_property() {
        forall(
            51,
            60,
            |r| {
                // generate a random op trace
                let ops: Vec<(u8, u64, usize)> = (0..r.range(5, 80))
                    .map(|_| (r.range(0, 3) as u8, r.range(0, 6) as u64, r.range(0, 200)))
                    .collect();
                ops
            },
            |ops| {
                let mut kv = KvCache::new(32, 16);
                for &(op, id, tokens) in ops {
                    match op {
                        0 => {
                            let _ = kv.ensure(id, tokens);
                        }
                        1 => kv.trim(id, tokens),
                        _ => kv.release(id),
                    }
                    kv.check_invariants()?;
                }
                check(true, "")
            },
        );
    }
}
