//! Continuous-batching scheduler with per-sequence look-ahead slots.
//!
//! Paper §3.2: "Scheduling uses a dedicated routine that computes lookahead
//! slots directly from SL_i^{(t)} and is applied uniformly to prefill,
//! decode, and chunked prefill."  Here that routine is
//! [`Scheduler::lookahead_tokens`]: the number of KV slots a sequence needs
//! for the next round is its current length + its granted SL + 1 (bonus).
//! Admission is FCFS; on KV pressure the most-recently admitted running
//! sequence is preempted (vLLM's recompute-preemption policy).

use std::collections::VecDeque;

use super::kv_cache::KvCache;
use super::request::{PriorityClass, SeqState};

/// Queue age (engine seconds) after which a waiting sequence is escalated
/// one priority rank — the aging escape hatch that keeps strict-priority
/// admission from starving best-effort work: after at most
/// `2 * AGING_ESCALATE_S` of waiting, a best-effort sequence competes at
/// interactive rank and wins its FCFS tie-break (older queue position).
pub const AGING_ESCALATE_S: f64 = 30.0;

/// Effective admission rank of a waiting sequence at engine time `now`:
/// the class rank minus one rank per [`AGING_ESCALATE_S`] of queue wait
/// (saturating at interactive rank 0).
pub fn effective_rank(seq: &SeqState, now: f64) -> usize {
    let waited = (now - seq.arrival).max(0.0);
    let boost = (waited / AGING_ESCALATE_S) as usize;
    seq.class.rank().saturating_sub(boost)
}

/// Scheduling decision for one step.
#[derive(Clone, Debug, Default)]
pub struct ScheduleOutcome {
    /// indices (into the running list) scheduled this step
    pub scheduled: Vec<usize>,
    /// sequences preempted back to the waiting queue this step (ids)
    pub preempted: Vec<u64>,
    /// number of admissions performed this step
    pub admitted: usize,
}

/// FCFS continuous-batching scheduler.
#[derive(Clone, Debug)]
pub struct Scheduler {
    /// Maximum sequences scheduled per step.
    pub max_batch: usize,
}

impl Scheduler {
    /// Construct a scheduler with the given batch bound.
    pub fn new(max_batch: usize) -> Scheduler {
        Scheduler { max_batch }
    }

    /// KV slots a sequence needs for the next round under granted SL `sl`
    /// (pre-mapping: context + speculative tokens + bonus).
    pub fn lookahead_tokens(seq_len: usize, sl: usize) -> usize {
        seq_len + sl + 1
    }

    /// Admit from `waiting` into `running` while the batch has room and the
    /// KV manager can hold each prompt + one look-ahead slot.
    pub fn admit(
        &self,
        waiting: &mut VecDeque<SeqState>,
        running: &mut Vec<SeqState>,
        kv: &mut KvCache,
    ) -> usize {
        self.admit_bounded(waiting, running, kv, usize::MAX)
    }

    /// [`Scheduler::admit`] with an additional batch bound: admission stops
    /// at `min(max_batch, limit)`.  The fleet controller's admission
    /// throttle ([`crate::spec::control`]) passes a fraction of
    /// `max_batch` here under saturation; everything else admits with
    /// `usize::MAX` (no extra bound).  Sequences already running above the
    /// limit are never evicted — the bound only gates new admissions.
    pub fn admit_bounded(
        &self,
        waiting: &mut VecDeque<SeqState>,
        running: &mut Vec<SeqState>,
        kv: &mut KvCache,
        limit: usize,
    ) -> usize {
        let bound = self.max_batch.min(limit);
        let mut admitted = 0;
        while running.len() < bound {
            let Some(seq) = waiting.front() else { break };
            let need = Self::lookahead_tokens(seq.tokens.len(), 1);
            if kv.ensure(seq.id, need).is_err() {
                break; // FCFS head-of-line: don't skip ahead
            }
            running.push(waiting.pop_front().unwrap());
            admitted += 1;
        }
        admitted
    }

    /// Priority-aware admission: strict-priority by [`effective_rank`]
    /// (class rank with queue-age escalation), FCFS within a rank.  When
    /// every waiting sequence shares one class — the entire pre-tenancy
    /// workload — this delegates to [`Scheduler::admit_bounded`] and is
    /// bit-identical to plain FCFS, because equal ranks tie-break on queue
    /// position.  Like FCFS, the best candidate blocks head-of-line: a
    /// lower-priority follower is never admitted past a blocked leader, so
    /// KV pressure cannot invert the priority order.
    pub fn admit_prioritized(
        &self,
        waiting: &mut VecDeque<SeqState>,
        running: &mut Vec<SeqState>,
        kv: &mut KvCache,
        limit: usize,
        now: f64,
    ) -> usize {
        let uniform = waiting
            .iter()
            .all(|s| s.class == waiting.front().map_or(s.class, |f| f.class));
        if uniform {
            return self.admit_bounded(waiting, running, kv, limit);
        }
        let bound = self.max_batch.min(limit);
        let mut admitted = 0;
        while running.len() < bound {
            let Some(best) = (0..waiting.len())
                .min_by_key(|&i| (effective_rank(&waiting[i], now), i))
            else {
                break;
            };
            let seq = &waiting[best];
            let need = Self::lookahead_tokens(seq.tokens.len(), 1);
            if kv.ensure(seq.id, need).is_err() {
                break; // priority head-of-line: don't skip past the best
            }
            let seq = waiting.remove(best).unwrap();
            running.push(seq);
            admitted += 1;
        }
        admitted
    }

    /// Preempt the most recently admitted best-effort sequence to make
    /// room for a blocked higher-class arrival (tenancy pressure valve —
    /// distinct from the KV-pressure preemption in
    /// [`Scheduler::reserve_lookahead`]).  The victim keeps its arrival
    /// time and accrued state and re-queues at the front, so its `waited`
    /// accounting keeps counting.  Returns the victim id, if any.
    pub fn preempt_best_effort(
        &self,
        running: &mut Vec<SeqState>,
        kv: &mut KvCache,
        waiting: &mut VecDeque<SeqState>,
    ) -> Option<u64> {
        let idx = running
            .iter()
            .rposition(|s| s.class == PriorityClass::BestEffort)?;
        let mut victim = running.remove(idx);
        kv.release(victim.id);
        victim.preemptions += 1;
        let id = victim.id;
        waiting.push_front(victim);
        Some(id)
    }

    /// Pre-map look-ahead slots for the granted SLs; preempts victims (from
    /// the tail = most recently admitted) until the batch fits.  Returns the
    /// outcome; `sls` is shortened in lock-step when sequences are dropped.
    pub fn reserve_lookahead(
        &self,
        running: &mut Vec<SeqState>,
        sls: &mut Vec<usize>,
        kv: &mut KvCache,
        waiting: &mut VecDeque<SeqState>,
    ) -> ScheduleOutcome {
        assert_eq!(running.len(), sls.len());
        let mut out = ScheduleOutcome::default();
        let mut i = 0;
        while i < running.len() {
            let need = Self::lookahead_tokens(running[i].tokens.len(), sls[i]);
            match kv.ensure(running[i].id, need) {
                Ok(()) => i += 1,
                Err(_) => {
                    // preempt the most recently admitted (tail) — unless the
                    // tail is the victim-less case (single sequence): then
                    // degrade its SL to the minimum and retry once.
                    if running.len() == 1 {
                        if sls[0] > 1 {
                            sls[0] = 1;
                            continue;
                        }
                        break; // cannot even hold one sequence: caller's OOM
                    }
                    let victim_idx = running.len() - 1;
                    let mut victim = running.remove(victim_idx);
                    sls.remove(victim_idx);
                    kv.release(victim.id);
                    victim.preemptions += 1;
                    out.preempted.push(victim.id);
                    waiting.push_front(victim);
                    if victim_idx == i {
                        continue;
                    }
                }
            }
        }
        out.scheduled = (0..running.len()).collect();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::Request;

    fn seq(id: u64, prompt_len: usize) -> SeqState {
        SeqState::from_request(Request::new(
            id,
            vec![65; prompt_len],
            Default::default(),
        ))
    }

    #[test]
    fn lookahead_includes_bonus() {
        assert_eq!(Scheduler::lookahead_tokens(10, 4), 15);
        assert_eq!(Scheduler::lookahead_tokens(0, 0), 1);
    }

    #[test]
    fn admits_up_to_batch() {
        let s = Scheduler::new(2);
        let mut waiting: VecDeque<_> = (0..4).map(|i| seq(i, 8)).collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(64, 16);
        let n = s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(n, 2);
        assert_eq!(running.len(), 2);
        assert_eq!(waiting.len(), 2);
    }

    #[test]
    fn admit_bounded_gates_below_max_batch() {
        let s = Scheduler::new(4);
        let mut waiting: VecDeque<_> = (0..4).map(|i| seq(i, 8)).collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(64, 16);
        let n = s.admit_bounded(&mut waiting, &mut running, &mut kv, 2);
        assert_eq!(n, 2, "the controller limit wins over max_batch");
        // an over-full batch (preemption re-queue churn) admits nothing
        // but is never evicted by the bound
        let n = s.admit_bounded(&mut waiting, &mut running, &mut kv, 1);
        assert_eq!(n, 0);
        assert_eq!(running.len(), 2);
        // MAX restores plain admit semantics
        let n = s.admit_bounded(&mut waiting, &mut running, &mut kv, usize::MAX);
        assert_eq!(n, 2);
        assert_eq!(running.len(), 4);
    }

    #[test]
    fn admission_blocked_by_kv() {
        let s = Scheduler::new(8);
        let mut waiting: VecDeque<_> = (0..4).map(|i| seq(i, 64)).collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(5, 16); // 5 blocks = 80 tokens capacity
        s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(running.len(), 1); // 64+1 tokens -> 5 blocks, second won't fit
        assert_eq!(waiting.len(), 3);
    }

    #[test]
    fn reserve_grows_tables() {
        let s = Scheduler::new(4);
        let mut running = vec![seq(1, 10), seq(2, 10)];
        let mut sls = vec![4usize, 8usize];
        let mut kv = KvCache::new(64, 4);
        let mut waiting = VecDeque::new();
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert!(out.preempted.is_empty());
        // seq 1 needs 10+4+1=15 tokens -> 4 blocks; seq 2 needs 19 -> 5
        assert_eq!(kv.table(1).len(), 4);
        assert_eq!(kv.table(2).len(), 5);
    }

    #[test]
    fn preempts_tail_under_pressure() {
        let s = Scheduler::new(4);
        let mut running = vec![seq(1, 40), seq(2, 40), seq(3, 40)];
        let mut sls = vec![4usize, 4, 4];
        // block_size 8: ctx 40 -> 5 blocks each (15 total fits in 16);
        // look-ahead 45 -> 6 blocks each (18 total does not)
        let mut kv = KvCache::new(16, 8);
        for sq in &running {
            kv.ensure(sq.id, sq.tokens.len()).unwrap();
        }
        let mut waiting = VecDeque::new();
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert_eq!(out.preempted, vec![3]);
        assert_eq!(running.len(), 2);
        assert_eq!(sls.len(), 2);
        assert_eq!(waiting.front().unwrap().id, 3);
        assert_eq!(waiting.front().unwrap().preemptions, 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fcfs_head_of_line_blocks_smaller_followers() {
        // the head prompt (100 tokens -> 7 blocks) cannot fit in 5 blocks;
        // FCFS must NOT skip ahead to the small follower that would fit
        let s = Scheduler::new(8);
        let mut waiting: VecDeque<_> = [seq(1, 100), seq(2, 8)].into_iter().collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(5, 16);
        let n = s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(n, 0, "nothing may be admitted past a blocked head");
        assert!(running.is_empty());
        assert_eq!(
            waiting.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![1, 2],
            "queue order must be preserved"
        );
        assert_eq!(kv.used_blocks(), 0);
    }

    #[test]
    fn preempted_sequence_readmitted_before_older_waiting() {
        // a preemption victim goes to the FRONT of the waiting queue
        // (push_front fairness): it is re-admitted before requests that
        // arrived while it was running
        let s = Scheduler::new(2);
        let mut running = vec![seq(1, 40), seq(2, 40)];
        let mut sls = vec![8usize, 8];
        // 40 tokens -> 5 blocks each (block 8); 49-token look-ahead needs 7
        // blocks each: 14 > 11 total, so the tail (seq 2) is preempted
        let mut kv = KvCache::new(11, 8);
        for sq in &running {
            kv.ensure(sq.id, sq.tokens.len()).unwrap();
        }
        let mut waiting: VecDeque<_> = [seq(9, 8)].into_iter().collect();
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert_eq!(out.preempted, vec![2]);
        assert_eq!(
            waiting.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![2, 9],
            "victim must queue ahead of the newer arrival"
        );
        // free the pressure and re-admit: the victim comes back first
        kv.release(1);
        running.clear();
        let n = s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(n, 2);
        assert_eq!(running[0].id, 2, "preempted sequence re-admitted first");
        assert_eq!(running[1].id, 9);
    }

    #[test]
    fn preempt_resume_cycle_keeps_counters_and_order_consistent() {
        // full preempt -> resume cycle: the victim re-queues at the FRONT,
        // is re-admitted first (no starvation), and the admitted/preempted
        // outcome counters match the queue transitions exactly
        let s = Scheduler::new(2);
        // 12 blocks of 8: admission (41-token lookahead -> 6 blocks each)
        // exactly fits both; the SL-8 lookahead (49 -> 7 each) cannot
        let mut kv = KvCache::new(12, 8);
        let mut waiting: VecDeque<_> = [seq(1, 40), seq(2, 40)].into_iter().collect();
        let mut running = Vec::new();
        // cycle 1: admit both
        let admitted = s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(admitted, 2);
        assert_eq!(running.len() + waiting.len(), 2, "requests conserved");
        // cycle 2: big SLs blow the KV budget -> tail preempted
        let mut sls = vec![8usize, 8];
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert_eq!(out.preempted, vec![2]);
        assert_eq!(out.admitted, 0, "reserve never admits");
        assert_eq!(running.len(), 1);
        assert_eq!(waiting.front().unwrap().id, 2, "victim re-queued at front");
        assert_eq!(waiting.front().unwrap().preemptions, 1);
        assert_eq!(running.len() + waiting.len(), 2, "requests conserved");
        kv.check_invariants().unwrap();
        // cycle 3: resume — seq 1 retires (release), victim re-admits and
        // its lookahead now fits; the preemption counter does not move
        kv.release(1);
        running.clear();
        let admitted = s.admit(&mut waiting, &mut running, &mut kv);
        assert_eq!(admitted, 1);
        assert_eq!(running[0].id, 2);
        assert_eq!(running[0].preemptions, 1, "counter survives the round trip");
        let mut sls = vec![8usize];
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert!(out.preempted.is_empty(), "resumed victim must not thrash");
        assert!(waiting.is_empty());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn repeated_preemption_cycles_never_starve_the_victim() {
        // under sustained pressure the same victim bounces, but each cycle
        // it re-queues at the front, so it is always next in line — its
        // preemption count grows, proof it kept being the one re-admitted
        let s = Scheduler::new(2);
        let mut kv = KvCache::new(10, 8);
        let mut waiting: VecDeque<_> = [seq(1, 36), seq(2, 36)].into_iter().collect();
        let mut running = Vec::new();
        for cycle in 1..=3 {
            s.admit(&mut waiting, &mut running, &mut kv);
            let mut sls = vec![8usize; running.len()];
            let out =
                s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
            assert_eq!(out.preempted, vec![2], "cycle {cycle}");
            assert_eq!(waiting.front().unwrap().id, 2, "cycle {cycle}: front spot");
            assert_eq!(waiting.front().unwrap().preemptions, cycle);
            assert_eq!(running.len() + waiting.len(), 2, "requests conserved");
            // survivor keeps running (its lookahead was granted)
            assert_eq!(running[0].id, 1);
            kv.check_invariants().unwrap();
            // post-round reallocation (the apply stage's trim): the
            // survivor gives back its over-mapped lookahead block, so the
            // next cycle can re-admit the victim into the free batch slot
            kv.trim(1, 36);
        }
    }

    #[test]
    fn reserve_on_empty_running_is_a_clean_noop() {
        let s = Scheduler::new(4);
        let mut running: Vec<SeqState> = Vec::new();
        let mut sls: Vec<usize> = Vec::new();
        let mut kv = KvCache::new(4, 16);
        let mut waiting = VecDeque::new();
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert!(out.preempted.is_empty());
        assert!(out.scheduled.is_empty());
        assert_eq!(kv.used_blocks(), 0);
    }

    fn classed(id: u64, prompt_len: usize, class: PriorityClass) -> SeqState {
        let mut s = seq(id, prompt_len);
        s.class = class;
        s
    }

    #[test]
    fn uniform_class_prioritized_admission_matches_fcfs() {
        let s = Scheduler::new(3);
        let build = || -> VecDeque<SeqState> { (0..5).map(|i| seq(i, 8)).collect() };
        let mut fcfs_waiting = build();
        let mut fcfs_running = Vec::new();
        let mut fcfs_kv = KvCache::new(64, 16);
        let a = s.admit_bounded(&mut fcfs_waiting, &mut fcfs_running, &mut fcfs_kv, 8);
        let mut pri_waiting = build();
        let mut pri_running = Vec::new();
        let mut pri_kv = KvCache::new(64, 16);
        let b = s.admit_prioritized(&mut pri_waiting, &mut pri_running, &mut pri_kv, 8, 0.0);
        assert_eq!(a, b);
        assert_eq!(
            fcfs_running.iter().map(|q| q.id).collect::<Vec<_>>(),
            pri_running.iter().map(|q| q.id).collect::<Vec<_>>(),
        );
        assert_eq!(
            fcfs_waiting.iter().map(|q| q.id).collect::<Vec<_>>(),
            pri_waiting.iter().map(|q| q.id).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn prioritized_admission_orders_by_class_then_queue_position() {
        let s = Scheduler::new(2);
        let mut waiting: VecDeque<SeqState> = [
            classed(1, 8, PriorityClass::BestEffort),
            classed(2, 8, PriorityClass::Standard),
            classed(3, 8, PriorityClass::Interactive),
            classed(4, 8, PriorityClass::Interactive),
        ]
        .into_iter()
        .collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(64, 16);
        let n = s.admit_prioritized(&mut waiting, &mut running, &mut kv, 8, 0.0);
        assert_eq!(n, 2);
        assert_eq!(
            running.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![3, 4],
            "interactive admits first, FCFS within the class"
        );
        assert_eq!(
            waiting.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![1, 2],
            "passed-over sequences keep their queue order"
        );
    }

    #[test]
    fn aging_escalates_starved_best_effort_to_the_front() {
        let s = Scheduler::new(1);
        let mut aged = classed(1, 8, PriorityClass::BestEffort);
        aged.arrival = 0.0;
        let mut fresh = classed(2, 8, PriorityClass::Interactive);
        fresh.arrival = 2.0 * AGING_ESCALATE_S;
        let mut waiting: VecDeque<SeqState> = [aged, fresh].into_iter().collect();
        let mut running = Vec::new();
        let mut kv = KvCache::new(64, 16);
        // at now = 2 * AGING_ESCALATE_S the best-effort sequence has aged
        // two ranks (-> interactive) and wins the tie on queue position
        let now = 2.0 * AGING_ESCALATE_S;
        let n = s.admit_prioritized(&mut waiting, &mut running, &mut kv, 8, now);
        assert_eq!(n, 1);
        assert_eq!(running[0].id, 1, "aged best-effort admitted first");
    }

    #[test]
    fn preempt_best_effort_takes_youngest_and_requeues_front() {
        let s = Scheduler::new(4);
        let mut running = vec![
            classed(1, 8, PriorityClass::BestEffort),
            classed(2, 8, PriorityClass::Interactive),
            classed(3, 8, PriorityClass::BestEffort),
        ];
        let mut kv = KvCache::new(64, 16);
        for sq in &running {
            kv.ensure(sq.id, sq.tokens.len() + 1).unwrap();
        }
        let mut waiting: VecDeque<SeqState> =
            [classed(9, 8, PriorityClass::Interactive)].into_iter().collect();
        let victim = s.preempt_best_effort(&mut running, &mut kv, &mut waiting);
        assert_eq!(victim, Some(3), "most recently admitted best-effort goes");
        assert_eq!(
            running.iter().map(|q| q.id).collect::<Vec<_>>(),
            vec![1, 2],
            "interactive work is never a victim"
        );
        assert_eq!(waiting.front().unwrap().id, 3);
        assert_eq!(waiting.front().unwrap().preemptions, 1);
        kv.check_invariants().unwrap();
        // no best-effort left running -> nothing to preempt
        running.retain(|q| q.class != PriorityClass::BestEffort);
        assert_eq!(s.preempt_best_effort(&mut running, &mut kv, &mut waiting), None);
    }

    #[test]
    fn single_sequence_degrades_sl_instead_of_preempting() {
        let s = Scheduler::new(4);
        let mut running = vec![seq(1, 60)];
        let mut sls = vec![12usize];
        let mut kv = KvCache::new(4, 16); // 64 tokens: 60+12+1 won't fit
        let mut waiting = VecDeque::new();
        let out = s.reserve_lookahead(&mut running, &mut sls, &mut kv, &mut waiting);
        assert!(out.preempted.is_empty());
        assert_eq!(sls[0], 1); // degraded, 60+1+1=62 fits in 64
        assert_eq!(running.len(), 1);
    }
}
