//! Engine + per-request metrics: end-to-end latency, block efficiency
//! (tokens emitted per target invocation — the paper's BE), goodput,
//! throughput, straggler accounting, scheduler counters, and signal traces
//! for the analysis benches.
//!
//! Long-running serving safety: per-request summaries are kept in a bounded
//! retention window ([`RingBuf`]) while latency/TTFT distributions are
//! tracked by O(1) running [`Welford`] aggregates, so `/v1/metrics` memory
//! stays constant under sustained traffic.

use crate::util::json::Json;
use crate::util::ring::RingBuf;
use crate::util::stats::{percentile, Welford};

/// Default number of per-request summaries retained for percentile queries.
pub const DEFAULT_REQUEST_RETENTION: usize = 4096;

/// Summary of one finished request (denormalized for dump/analysis).
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    pub id: u64,
    pub latency: f64,
    pub ttft: f64,
    pub output_tokens: usize,
    pub rounds: usize,
    pub drafted: u64,
    pub accepted: u64,
    pub preemptions: usize,
}

/// Rolling engine-level metrics.
#[derive(Clone, Debug)]
pub struct EngineMetrics {
    /// engine steps executed
    pub steps: u64,
    /// speculative rounds (target verify invocations)
    pub verify_rounds: u64,
    /// autoregressive rounds
    pub ar_rounds: u64,
    /// sum over rounds of scheduled batch size (per-sequence target
    /// invocations — the BE denominator)
    pub seq_rounds: u64,
    /// tokens emitted across all sequences
    pub tokens_out: u64,
    /// draft tokens proposed / accepted
    pub drafted: u64,
    pub accepted: u64,
    /// sum over rounds of (max SL in round - per-seq SL), the straggler
    /// bubble: idle draft slots induced by batch synchronization
    pub straggler_bubble: u64,
    /// sequences admitted from the waiting queue (scheduler outcome)
    pub admitted: u64,
    /// sequences preempted back to the waiting queue under KV pressure
    pub preemptions: u64,
    /// sum over rounds of (pre-cap max SL - post-cap max SL): draft slots
    /// the batch-wide SL cap shaved off the round critical path (§3.3)
    pub cap_savings: u64,
    /// wall/virtual time spent in rounds
    pub busy_time: f64,
    /// current clock (set by the engine)
    pub now: f64,
    /// per-step scheduled batch size
    pub batch_hist: Welford,
    /// per-step granted max SL
    pub sl_hist: Welford,
    /// finished requests, all time (survives window eviction)
    pub completed: u64,
    /// output tokens of finished requests, all time
    pub completed_tokens: u64,
    /// all-time end-to-end latency distribution (O(1) memory)
    pub latency: Welford,
    /// all-time time-to-first-token distribution (O(1) memory)
    pub ttft: Welford,
    /// bounded window of recent finished-request summaries (percentiles,
    /// traces); evicts oldest beyond its retention capacity
    pub requests: RingBuf<RequestMetrics>,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        EngineMetrics::with_retention(DEFAULT_REQUEST_RETENTION)
    }
}

impl EngineMetrics {
    /// Construct with an explicit per-request retention window.
    pub fn with_retention(retention: usize) -> EngineMetrics {
        EngineMetrics {
            steps: 0,
            verify_rounds: 0,
            ar_rounds: 0,
            seq_rounds: 0,
            tokens_out: 0,
            drafted: 0,
            accepted: 0,
            straggler_bubble: 0,
            admitted: 0,
            preemptions: 0,
            cap_savings: 0,
            busy_time: 0.0,
            now: 0.0,
            batch_hist: Welford::new(),
            sl_hist: Welford::new(),
            completed: 0,
            completed_tokens: 0,
            latency: Welford::new(),
            ttft: Welford::new(),
            requests: RingBuf::new(retention.max(1)),
        }
    }

    /// Record a finished request: updates the all-time aggregates and the
    /// bounded window together (always use this rather than pushing into
    /// [`EngineMetrics::requests`] directly).
    pub fn record_request(&mut self, req: RequestMetrics) {
        self.completed += 1;
        self.completed_tokens += req.output_tokens as u64;
        self.latency.push(req.latency);
        self.ttft.push(req.ttft);
        self.requests.push(req);
    }

    /// Block efficiency: mean tokens emitted per sequence per target
    /// invocation — the paper's BE metric (Table 1).
    pub fn block_efficiency(&self) -> f64 {
        if self.seq_rounds == 0 {
            0.0
        } else {
            self.tokens_out as f64 / self.seq_rounds as f64
        }
    }

    /// Draft-token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Tokens per second over the busy window.
    pub fn throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.busy_time
        }
    }

    /// Mean end-to-end request latency (the paper's primary metric) — the
    /// all-time aggregate, unaffected by window eviction.
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// p99 end-to-end latency over the retained request window.
    pub fn p99_latency(&self) -> f64 {
        percentile(
            &self.requests.iter().map(|r| r.latency).collect::<Vec<_>>(),
            0.99,
        )
    }

    /// Goodput: completed output tokens per second of busy time.
    pub fn goodput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            return 0.0;
        }
        self.completed_tokens as f64 / self.busy_time
    }

    /// Fold another engine's metrics into this one — the router uses this to
    /// aggregate `/v1/metrics` across replicas.  Counters add; clocks take
    /// the max; distributions merge; request windows concatenate (subject to
    /// this window's retention bound).  Note `busy_time` sums to *total*
    /// busy seconds across replicas, so the merged `throughput()` is a
    /// per-busy-second rate that stays flat in replica count; for fleet
    /// throughput divide token totals by the makespan (max per-replica
    /// `busy_time`) as `EngineRouter::metrics_json` does.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.steps += other.steps;
        self.verify_rounds += other.verify_rounds;
        self.ar_rounds += other.ar_rounds;
        self.seq_rounds += other.seq_rounds;
        self.tokens_out += other.tokens_out;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.straggler_bubble += other.straggler_bubble;
        self.admitted += other.admitted;
        self.preemptions += other.preemptions;
        self.cap_savings += other.cap_savings;
        self.busy_time += other.busy_time;
        self.now = self.now.max(other.now);
        self.batch_hist.merge(&other.batch_hist);
        self.sl_hist.merge(&other.sl_hist);
        self.completed += other.completed;
        self.completed_tokens += other.completed_tokens;
        self.latency.merge(&other.latency);
        self.ttft.merge(&other.ttft);
        for r in other.requests.iter() {
            self.requests.push(r.clone());
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("steps", self.steps)
            .set("verify_rounds", self.verify_rounds)
            .set("ar_rounds", self.ar_rounds)
            .set("tokens_out", self.tokens_out)
            .set("drafted", self.drafted)
            .set("accepted", self.accepted)
            .set("admitted", self.admitted)
            .set("preemptions", self.preemptions)
            .set("cap_savings", self.cap_savings)
            .set("acceptance_rate", self.acceptance_rate())
            .set("block_efficiency", self.block_efficiency())
            .set("throughput", self.throughput())
            .set("goodput", self.goodput())
            .set("mean_latency", self.mean_latency())
            .set("p99_latency", self.p99_latency())
            .set("mean_ttft", self.ttft.mean())
            .set("straggler_bubble", self.straggler_bubble)
            .set("busy_time", self.busy_time)
            .set("requests", self.completed)
            .set("window_requests", self.requests.len() as u64)
            .set("window_evicted", self.requests.evicted())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(lat: f64, toks: usize) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            latency: lat,
            ttft: lat * 0.1,
            output_tokens: toks,
            rounds: 10,
            drafted: 30,
            accepted: 20,
            preemptions: 0,
        }
    }

    #[test]
    fn block_efficiency_math() {
        let mut m = EngineMetrics::default();
        m.verify_rounds = 10;
        m.seq_rounds = 10;
        m.tokens_out = 38;
        assert!((m.block_efficiency() - 3.8).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.block_efficiency(), 0.0);
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.goodput(), 0.0);
    }

    #[test]
    fn latency_aggregation() {
        let mut m = EngineMetrics::default();
        m.record_request(req(2.0, 10));
        m.record_request(req(4.0, 30));
        assert!((m.mean_latency() - 3.0).abs() < 1e-12);
        m.busy_time = 10.0;
        assert!((m.goodput() - 4.0).abs() < 1e-12);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn retention_window_bounds_memory_but_keeps_aggregates() {
        let mut m = EngineMetrics::with_retention(8);
        for i in 0..100 {
            m.record_request(req(1.0 + i as f64, 5));
        }
        // window bounded ...
        assert_eq!(m.requests.len(), 8);
        assert_eq!(m.requests.evicted(), 92);
        // ... while the all-time aggregates still see every request
        assert_eq!(m.completed, 100);
        assert_eq!(m.completed_tokens, 500);
        assert_eq!(m.latency.count(), 100);
        let expect_mean = (0..100).map(|i| 1.0 + i as f64).sum::<f64>() / 100.0;
        assert!((m.mean_latency() - expect_mean).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_counters_and_distributions() {
        let mut a = EngineMetrics::default();
        a.steps = 10;
        a.tokens_out = 100;
        a.admitted = 4;
        a.preemptions = 1;
        a.cap_savings = 7;
        a.busy_time = 2.0;
        a.now = 5.0;
        a.record_request(req(2.0, 10));
        let mut b = EngineMetrics::default();
        b.steps = 20;
        b.tokens_out = 50;
        b.admitted = 6;
        b.preemptions = 2;
        b.cap_savings = 3;
        b.busy_time = 3.0;
        b.now = 4.0;
        b.record_request(req(4.0, 20));
        a.merge(&b);
        assert_eq!(a.steps, 30);
        assert_eq!(a.tokens_out, 150);
        assert_eq!(a.admitted, 10);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.cap_savings, 10);
        assert!((a.busy_time - 5.0).abs() < 1e-12);
        assert!((a.now - 5.0).abs() < 1e-12);
        assert_eq!(a.completed, 2);
        assert!((a.mean_latency() - 3.0).abs() < 1e-12);
        assert_eq!(a.requests.len(), 2);
    }

    #[test]
    fn json_contains_core_fields() {
        let m = EngineMetrics::default();
        let s = m.to_json().to_string();
        assert!(s.contains("block_efficiency"));
        assert!(s.contains("straggler_bubble"));
        assert!(s.contains("admitted"));
        assert!(s.contains("preemptions"));
        assert!(s.contains("cap_savings"));
        assert!(s.contains("window_requests"));
    }
}
