//! Engine + per-request metrics: end-to-end latency, block efficiency
//! (tokens emitted per target invocation — the paper's BE), goodput,
//! throughput, straggler accounting, and signal traces for the analysis
//! benches.

use crate::util::json::Json;
use crate::util::stats::{mean, percentile, Welford};

/// Summary of one finished request (denormalized for dump/analysis).
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    pub id: u64,
    pub latency: f64,
    pub ttft: f64,
    pub output_tokens: usize,
    pub rounds: usize,
    pub drafted: u64,
    pub accepted: u64,
    pub preemptions: usize,
}

/// Rolling engine-level metrics.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// engine steps executed
    pub steps: u64,
    /// speculative rounds (target verify invocations)
    pub verify_rounds: u64,
    /// autoregressive rounds
    pub ar_rounds: u64,
    /// sum over rounds of scheduled batch size (per-sequence target
    /// invocations — the BE denominator)
    pub seq_rounds: u64,
    /// tokens emitted across all sequences
    pub tokens_out: u64,
    /// draft tokens proposed / accepted
    pub drafted: u64,
    pub accepted: u64,
    /// sum over rounds of (max SL in round - per-seq SL), the straggler
    /// bubble: idle draft slots induced by batch synchronization
    pub straggler_bubble: u64,
    /// wall/virtual time spent in rounds
    pub busy_time: f64,
    /// current clock (set by the engine)
    pub now: f64,
    /// per-step scheduled batch size
    pub batch_hist: Welford,
    /// per-step granted max SL
    pub sl_hist: Welford,
    /// finished-request summaries
    pub requests: Vec<RequestMetrics>,
}

impl EngineMetrics {
    /// Block efficiency: mean tokens emitted per sequence per target
    /// invocation — the paper's BE metric (Table 1).
    pub fn block_efficiency(&self) -> f64 {
        if self.seq_rounds == 0 {
            0.0
        } else {
            self.tokens_out as f64 / self.seq_rounds as f64
        }
    }

    /// Draft-token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Tokens per second over the busy window.
    pub fn throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.busy_time
        }
    }

    /// Mean end-to-end request latency (the paper's primary metric).
    pub fn mean_latency(&self) -> f64 {
        mean(&self.requests.iter().map(|r| r.latency).collect::<Vec<_>>())
    }

    pub fn p99_latency(&self) -> f64 {
        percentile(
            &self.requests.iter().map(|r| r.latency).collect::<Vec<_>>(),
            0.99,
        )
    }

    /// Goodput: completed output tokens per second of busy time.
    pub fn goodput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            return 0.0;
        }
        let done: u64 = self.requests.iter().map(|r| r.output_tokens as u64).sum();
        done as f64 / self.busy_time
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("steps", self.steps)
            .set("verify_rounds", self.verify_rounds)
            .set("ar_rounds", self.ar_rounds)
            .set("tokens_out", self.tokens_out)
            .set("drafted", self.drafted)
            .set("accepted", self.accepted)
            .set("acceptance_rate", self.acceptance_rate())
            .set("block_efficiency", self.block_efficiency())
            .set("throughput", self.throughput())
            .set("goodput", self.goodput())
            .set("mean_latency", self.mean_latency())
            .set("p99_latency", self.p99_latency())
            .set("straggler_bubble", self.straggler_bubble)
            .set("busy_time", self.busy_time)
            .set("requests", self.requests.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(lat: f64, toks: usize) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            latency: lat,
            ttft: lat * 0.1,
            output_tokens: toks,
            rounds: 10,
            drafted: 30,
            accepted: 20,
            preemptions: 0,
        }
    }

    #[test]
    fn block_efficiency_math() {
        let mut m = EngineMetrics::default();
        m.verify_rounds = 10;
        m.seq_rounds = 10;
        m.tokens_out = 38;
        assert!((m.block_efficiency() - 3.8).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.block_efficiency(), 0.0);
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.goodput(), 0.0);
    }

    #[test]
    fn latency_aggregation() {
        let mut m = EngineMetrics::default();
        m.requests.push(req(2.0, 10));
        m.requests.push(req(4.0, 30));
        assert!((m.mean_latency() - 3.0).abs() < 1e-12);
        m.busy_time = 10.0;
        assert!((m.goodput() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn json_contains_core_fields() {
        let m = EngineMetrics::default();
        let s = m.to_json().to_string();
        assert!(s.contains("block_efficiency"));
        assert!(s.contains("straggler_bubble"));
    }
}
