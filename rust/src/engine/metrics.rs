//! Engine + per-request metrics: end-to-end latency, time-to-first-token,
//! inter-token latency, block efficiency (tokens emitted per target
//! invocation — the paper's BE), goodput, throughput, straggler accounting,
//! scheduler counters, and signal traces for the analysis benches.
//!
//! Long-running serving safety: per-request summaries are kept in a bounded
//! retention window ([`RingBuf`]) while latency/TTFT/ITL distributions are
//! tracked by O(1) running [`Welford`] aggregates, so `/v1/metrics` memory
//! stays constant under sustained traffic.
//!
//! For cross-thread reporting (the router's `/v1/metrics` path) a
//! [`MetricsSnapshot`] is the wire type: pre-reduced scalars plus the
//! requested percentiles, so a snapshot never clones the retained request
//! window over a channel.

use std::collections::HashMap;

use crate::engine::request::PriorityClass;
use crate::util::json::Json;
use crate::util::ring::RingBuf;
use crate::util::stats::{percentile, percentile_sorted, Welford};

/// Per-priority-class rollup: completions, deadline/SLO attainment, and
/// granted-SL totals (the tight- vs slack-deadline SL evidence the eval
/// report surfaces).  Indexed by [`PriorityClass::rank`] in
/// [`EngineMetrics::classes`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassMetrics {
    /// Finished requests of this class.
    pub completed: u64,
    /// Output tokens of finished requests of this class.
    pub completed_tokens: u64,
    /// Finished requests that carried a deadline.
    pub with_deadline: u64,
    /// Deadline-carrying requests that finished within their deadline.
    pub deadline_met: u64,
    /// Sum of granted per-round speculation lengths over sequences of this
    /// class (post cap/control/deadline clamps).
    pub sl_sum: u64,
    /// Sequence-rounds contributing to `sl_sum`.
    pub sl_rounds: u64,
}

impl ClassMetrics {
    /// SLO attainment: fraction of deadline-carrying completions that met
    /// their deadline; `1.0` when the class saw no deadlines (vacuously
    /// attained, and the neutral value for report columns).
    pub fn attainment(&self) -> f64 {
        if self.with_deadline == 0 {
            1.0
        } else {
            self.deadline_met as f64 / self.with_deadline as f64
        }
    }

    /// Mean granted SL per sequence-round for this class (0 when the class
    /// never ran).
    pub fn mean_sl(&self) -> f64 {
        if self.sl_rounds == 0 {
            0.0
        } else {
            self.sl_sum as f64 / self.sl_rounds as f64
        }
    }

    /// Fold another rollup into this one (counters add).
    pub fn merge(&mut self, other: &ClassMetrics) {
        self.completed += other.completed;
        self.completed_tokens += other.completed_tokens;
        self.with_deadline += other.with_deadline;
        self.deadline_met += other.deadline_met;
        self.sl_sum += other.sl_sum;
        self.sl_rounds += other.sl_rounds;
    }

    fn to_json(self) -> Json {
        Json::obj()
            .set("requests", self.completed)
            .set("tokens_out", self.completed_tokens)
            .set("with_deadline", self.with_deadline)
            .set("deadline_met", self.deadline_met)
            .set("attainment", self.attainment())
            .set("mean_sl", self.mean_sl())
    }
}

/// Per-tenant completion totals ("" = unattributed traffic).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantMetrics {
    /// Finished requests attributed to the tenant.
    pub completed: u64,
    /// Output tokens of those requests.
    pub completed_tokens: u64,
}

/// Serialize a per-class array as a `{class_name: rollup}` JSON object.
fn classes_json(classes: &[ClassMetrics; 3]) -> Json {
    let mut j = Json::obj();
    for c in PriorityClass::ALL {
        j = j.set(c.name(), classes[c.rank()].to_json());
    }
    j
}

/// Serialize per-tenant totals (sorted by tenant name for deterministic
/// output); `busy_time` turns token totals into per-tenant goodput.
fn tenants_json(tenants: &HashMap<String, TenantMetrics>, busy_time: f64) -> Json {
    let mut names: Vec<&String> = tenants.keys().collect();
    names.sort();
    let mut j = Json::obj();
    for name in names {
        let t = tenants[name];
        let goodput = if busy_time <= 0.0 {
            0.0
        } else {
            t.completed_tokens as f64 / busy_time
        };
        j = j.set(
            name,
            Json::obj()
                .set("requests", t.completed)
                .set("tokens_out", t.completed_tokens)
                .set("goodput", goodput),
        );
    }
    j
}

/// Fold per-tenant totals from `other` into `into` (counters add).
fn merge_tenants(
    into: &mut HashMap<String, TenantMetrics>,
    other: &HashMap<String, TenantMetrics>,
) {
    for (name, t) in other {
        let e = into.entry(name.clone()).or_default();
        e.completed += t.completed;
        e.completed_tokens += t.completed_tokens;
    }
}

/// Default number of per-request summaries retained for percentile queries.
pub const DEFAULT_REQUEST_RETENTION: usize = 4096;

/// Percentiles a [`MetricsSnapshot`] reports when the caller does not ask
/// for a specific set.
pub const DEFAULT_QUANTILES: &[f64] = &[0.5, 0.9, 0.99];

/// Summary of one finished request (denormalized for dump/analysis).
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    /// Request id (router-global on the serving path).
    pub id: u64,
    /// End-to-end latency in engine seconds (arrival → finished).
    pub latency: f64,
    /// Time to first token in engine seconds (arrival → first delta).
    pub ttft: f64,
    /// Mean inter-token latency in engine seconds (0 when fewer than two
    /// output tokens were produced).
    pub itl: f64,
    /// Output tokens produced.
    pub output_tokens: usize,
    /// Engine rounds the request participated in.
    pub rounds: usize,
    /// Draft tokens proposed for this request.
    pub drafted: u64,
    /// Draft tokens accepted for this request.
    pub accepted: u64,
    /// Times the request was preempted under KV pressure.
    pub preemptions: usize,
    /// Tenant the request is attributed to ("" = unattributed).
    pub tenant: String,
    /// Scheduling priority class of the request.
    pub class: PriorityClass,
    /// Whether the request met its deadline (`None` = no deadline).
    pub deadline_met: Option<bool>,
}

/// Rolling engine-level metrics.
#[derive(Clone, Debug)]
pub struct EngineMetrics {
    /// engine steps executed
    pub steps: u64,
    /// speculative rounds (target verify invocations)
    pub verify_rounds: u64,
    /// autoregressive rounds
    pub ar_rounds: u64,
    /// sum over rounds of scheduled batch size (per-sequence target
    /// invocations — the BE denominator)
    pub seq_rounds: u64,
    /// tokens emitted across all sequences
    pub tokens_out: u64,
    /// draft tokens proposed
    pub drafted: u64,
    /// draft tokens accepted
    pub accepted: u64,
    /// sum over rounds of (max SL in round - per-seq SL), the straggler
    /// bubble: idle draft slots induced by batch synchronization
    pub straggler_bubble: u64,
    /// sequences admitted from the waiting queue (scheduler outcome)
    pub admitted: u64,
    /// sequences preempted back to the waiting queue under KV pressure
    pub preemptions: u64,
    /// sum over rounds of (pre-cap max SL - post-cap max SL): draft slots
    /// the batch-wide SL cap shaved off the round critical path (§3.3)
    pub cap_savings: u64,
    /// wall/virtual time spent in rounds
    pub busy_time: f64,
    /// current clock (set by the engine)
    pub now: f64,
    /// per-step scheduled batch size
    pub batch_hist: Welford,
    /// per-step granted max SL
    pub sl_hist: Welford,
    /// finished requests, all time (survives window eviction)
    pub completed: u64,
    /// output tokens of finished requests, all time
    pub completed_tokens: u64,
    /// all-time end-to-end latency distribution (O(1) memory)
    pub latency: Welford,
    /// all-time time-to-first-token distribution (O(1) memory)
    pub ttft: Welford,
    /// all-time per-request mean inter-token-latency distribution (O(1)
    /// memory; requests with fewer than two output tokens are excluded)
    pub itl: Welford,
    /// bounded window of recent finished-request summaries (percentiles,
    /// traces); evicts oldest beyond its retention capacity
    pub requests: RingBuf<RequestMetrics>,
    /// per-priority-class rollups (indexed by [`PriorityClass::rank`])
    pub classes: [ClassMetrics; 3],
    /// per-tenant completion totals ("" = unattributed)
    pub tenants: HashMap<String, TenantMetrics>,
    /// rounds where the deadline-slack clamp tightened a granted SL
    pub deadline_clamps: u64,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        EngineMetrics::with_retention(DEFAULT_REQUEST_RETENTION)
    }
}

impl EngineMetrics {
    /// Construct with an explicit per-request retention window.
    pub fn with_retention(retention: usize) -> EngineMetrics {
        EngineMetrics {
            steps: 0,
            verify_rounds: 0,
            ar_rounds: 0,
            seq_rounds: 0,
            tokens_out: 0,
            drafted: 0,
            accepted: 0,
            straggler_bubble: 0,
            admitted: 0,
            preemptions: 0,
            cap_savings: 0,
            busy_time: 0.0,
            now: 0.0,
            batch_hist: Welford::new(),
            sl_hist: Welford::new(),
            completed: 0,
            completed_tokens: 0,
            latency: Welford::new(),
            ttft: Welford::new(),
            itl: Welford::new(),
            requests: RingBuf::new(retention.max(1)),
            classes: [ClassMetrics::default(); 3],
            tenants: HashMap::new(),
            deadline_clamps: 0,
        }
    }

    /// Record a finished request: updates the all-time aggregates, the
    /// per-class/per-tenant rollups, and the bounded window together
    /// (always use this rather than pushing into
    /// [`EngineMetrics::requests`] directly).
    pub fn record_request(&mut self, req: RequestMetrics) {
        self.completed += 1;
        self.completed_tokens += req.output_tokens as u64;
        self.latency.push(req.latency);
        self.ttft.push(req.ttft);
        if req.output_tokens > 1 {
            self.itl.push(req.itl);
        }
        let cls = &mut self.classes[req.class.rank()];
        cls.completed += 1;
        cls.completed_tokens += req.output_tokens as u64;
        if let Some(met) = req.deadline_met {
            cls.with_deadline += 1;
            if met {
                cls.deadline_met += 1;
            }
        }
        let tenant = self.tenants.entry(req.tenant.clone()).or_default();
        tenant.completed += 1;
        tenant.completed_tokens += req.output_tokens as u64;
        self.requests.push(req);
    }

    /// Record the granted SL of one sequence-round for a class (called by
    /// the apply stage; feeds the per-class `mean_sl` report columns).
    pub fn record_class_sl(&mut self, class: PriorityClass, sl: usize) {
        let cls = &mut self.classes[class.rank()];
        cls.sl_sum += sl as u64;
        cls.sl_rounds += 1;
    }

    /// Overall SLO attainment across classes: fraction of deadline-carrying
    /// completions that met their deadline (1.0 when none carried one).
    pub fn slo_attainment(&self) -> f64 {
        let with: u64 = self.classes.iter().map(|c| c.with_deadline).sum();
        let met: u64 = self.classes.iter().map(|c| c.deadline_met).sum();
        if with == 0 {
            1.0
        } else {
            met as f64 / with as f64
        }
    }

    /// Block efficiency: mean tokens emitted per sequence per target
    /// invocation — the paper's BE metric (Table 1).
    pub fn block_efficiency(&self) -> f64 {
        if self.seq_rounds == 0 {
            0.0
        } else {
            self.tokens_out as f64 / self.seq_rounds as f64
        }
    }

    /// Draft-token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Tokens per second over the busy window.
    pub fn throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.busy_time
        }
    }

    /// Mean end-to-end request latency (the paper's primary metric) — the
    /// all-time aggregate, unaffected by window eviction.
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// p99 end-to-end latency over the retained request window.
    pub fn p99_latency(&self) -> f64 {
        percentile(
            &self.requests.iter().map(|r| r.latency).collect::<Vec<_>>(),
            0.99,
        )
    }

    /// Goodput: completed output tokens per second of busy time.
    pub fn goodput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            return 0.0;
        }
        self.completed_tokens as f64 / self.busy_time
    }

    /// Fold another engine's metrics into this one — an in-process helper
    /// for offline aggregation (benches, tests) where both windows are on
    /// hand.  The router's `/v1/metrics` path aggregates the cheap wire
    /// type instead: see [`MetricsSnapshot::merge`].  Counters add; clocks
    /// take the max; distributions merge; request windows concatenate
    /// (subject to this window's retention bound).  Note `busy_time` sums
    /// to *total* busy seconds across replicas, so the merged
    /// `throughput()` is a per-busy-second rate that stays flat in replica
    /// count; for fleet throughput divide token totals by the makespan
    /// (max per-replica `busy_time`) as `EngineRouter::metrics_json` does.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.steps += other.steps;
        self.verify_rounds += other.verify_rounds;
        self.ar_rounds += other.ar_rounds;
        self.seq_rounds += other.seq_rounds;
        self.tokens_out += other.tokens_out;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.straggler_bubble += other.straggler_bubble;
        self.admitted += other.admitted;
        self.preemptions += other.preemptions;
        self.cap_savings += other.cap_savings;
        self.busy_time += other.busy_time;
        self.now = self.now.max(other.now);
        self.batch_hist.merge(&other.batch_hist);
        self.sl_hist.merge(&other.sl_hist);
        self.completed += other.completed;
        self.completed_tokens += other.completed_tokens;
        self.latency.merge(&other.latency);
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        for (c, o) in self.classes.iter_mut().zip(&other.classes) {
            c.merge(o);
        }
        merge_tenants(&mut self.tenants, &other.tenants);
        self.deadline_clamps += other.deadline_clamps;
        for r in other.requests.iter() {
            self.requests.push(r.clone());
        }
    }

    /// Reduce to a cheap wire snapshot: every scalar counter, the Welford
    /// aggregates, and the given percentiles computed over the retained
    /// request window — but **not** the window itself.  This is what replica
    /// threads send back for `/v1/metrics`, keeping the reply O(#quantiles)
    /// instead of O(`metrics_retention`).
    pub fn snapshot(&self, quantiles: &[f64]) -> MetricsSnapshot {
        // sort each series once and index every requested quantile from it —
        // this runs on the replica's serving thread between engine steps, so
        // per-poll cost matters
        let mut lats: Vec<f64> = self.requests.iter().map(|r| r.latency).collect();
        let mut ttfts: Vec<f64> = self.requests.iter().map(|r| r.ttft).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        MetricsSnapshot {
            steps: self.steps,
            verify_rounds: self.verify_rounds,
            ar_rounds: self.ar_rounds,
            seq_rounds: self.seq_rounds,
            tokens_out: self.tokens_out,
            drafted: self.drafted,
            accepted: self.accepted,
            straggler_bubble: self.straggler_bubble,
            admitted: self.admitted,
            preemptions: self.preemptions,
            cap_savings: self.cap_savings,
            busy_time: self.busy_time,
            now: self.now,
            batch_hist: self.batch_hist.clone(),
            sl_hist: self.sl_hist.clone(),
            completed: self.completed,
            completed_tokens: self.completed_tokens,
            latency: self.latency.clone(),
            ttft: self.ttft.clone(),
            itl: self.itl.clone(),
            latency_quantiles: quantiles
                .iter()
                .map(|&q| (q, percentile_sorted(&lats, q)))
                .collect(),
            ttft_quantiles: quantiles
                .iter()
                .map(|&q| (q, percentile_sorted(&ttfts, q)))
                .collect(),
            window_len: self.requests.len() as u64,
            window_evicted: self.requests.evicted(),
            classes: self.classes,
            tenants: self.tenants.clone(),
            deadline_clamps: self.deadline_clamps,
        }
    }

    /// Serialize for the single-engine JSON paths (`dsde run --json`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("steps", self.steps)
            .set("verify_rounds", self.verify_rounds)
            .set("ar_rounds", self.ar_rounds)
            .set("tokens_out", self.tokens_out)
            .set("drafted", self.drafted)
            .set("accepted", self.accepted)
            .set("admitted", self.admitted)
            .set("preemptions", self.preemptions)
            .set("cap_savings", self.cap_savings)
            .set("acceptance_rate", self.acceptance_rate())
            .set("block_efficiency", self.block_efficiency())
            .set("throughput", self.throughput())
            .set("goodput", self.goodput())
            .set("mean_latency", self.mean_latency())
            .set("p99_latency", self.p99_latency())
            .set("mean_ttft", self.ttft.mean())
            .set("mean_itl", self.itl.mean())
            .set("straggler_bubble", self.straggler_bubble)
            .set("busy_time", self.busy_time)
            .set("requests", self.completed)
            .set("window_requests", self.requests.len() as u64)
            .set("window_evicted", self.requests.evicted())
            .set("slo_attainment", self.slo_attainment())
            .set("deadline_clamps", self.deadline_clamps)
            .set("slo", classes_json(&self.classes))
            .set("tenants", tenants_json(&self.tenants, self.busy_time))
    }
}

/// JSON key for a quantile/metric pair, e.g. `(0.99, "latency")` →
/// `"p99_latency"`.
fn quantile_key(metric: &str, q: f64) -> String {
    let pct = q * 100.0;
    if (pct - pct.round()).abs() < 1e-9 {
        format!("p{}_{metric}", pct.round() as u64)
    } else {
        format!("p{pct}_{metric}")
    }
}

/// A pre-reduced, cheaply clonable view of [`EngineMetrics`]: scalar
/// counters, the O(1) Welford aggregates, and a small set of percentiles
/// computed replica-side over the retained request window.
///
/// This is the `/v1/metrics` wire type — replicas reply with a snapshot
/// instead of cloning their full retention window over a channel, so a
/// high-frequency metrics scraper costs O(#quantiles) per replica per poll.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Engine steps executed.
    pub steps: u64,
    /// Speculative rounds (target verify invocations).
    pub verify_rounds: u64,
    /// Autoregressive rounds.
    pub ar_rounds: u64,
    /// Sum over rounds of scheduled batch size (the BE denominator).
    pub seq_rounds: u64,
    /// Tokens emitted across all sequences.
    pub tokens_out: u64,
    /// Draft tokens proposed.
    pub drafted: u64,
    /// Draft tokens accepted.
    pub accepted: u64,
    /// Idle draft slots induced by batch synchronization.
    pub straggler_bubble: u64,
    /// Sequences admitted from the waiting queue.
    pub admitted: u64,
    /// Sequences preempted back to the waiting queue under KV pressure.
    pub preemptions: u64,
    /// Draft slots the batch-wide SL cap shaved off round critical paths.
    pub cap_savings: u64,
    /// Wall/virtual seconds spent in rounds.
    pub busy_time: f64,
    /// Engine clock at snapshot time (max across replicas after a merge).
    pub now: f64,
    /// Per-step scheduled batch size distribution.
    pub batch_hist: Welford,
    /// Per-step granted max-SL distribution.
    pub sl_hist: Welford,
    /// Finished requests, all time.
    pub completed: u64,
    /// Output tokens of finished requests, all time.
    pub completed_tokens: u64,
    /// All-time end-to-end latency distribution.
    pub latency: Welford,
    /// All-time time-to-first-token distribution.
    pub ttft: Welford,
    /// All-time per-request mean inter-token-latency distribution.
    pub itl: Welford,
    /// `(quantile, value)` pairs for end-to-end latency over the retained
    /// window, in the order they were requested.
    pub latency_quantiles: Vec<(f64, f64)>,
    /// `(quantile, value)` pairs for TTFT over the retained window.
    pub ttft_quantiles: Vec<(f64, f64)>,
    /// Requests in the retention window the percentiles were computed over.
    pub window_len: u64,
    /// Requests evicted from the retention window so far.
    pub window_evicted: u64,
    /// Per-priority-class rollups (indexed by [`PriorityClass::rank`]).
    pub classes: [ClassMetrics; 3],
    /// Per-tenant completion totals ("" = unattributed).
    pub tenants: HashMap<String, TenantMetrics>,
    /// Rounds where the deadline-slack clamp tightened a granted SL.
    pub deadline_clamps: u64,
}

impl MetricsSnapshot {
    /// Overall SLO attainment across classes (1.0 when no request carried
    /// a deadline; see [`ClassMetrics::attainment`]).
    pub fn slo_attainment(&self) -> f64 {
        let with: u64 = self.classes.iter().map(|c| c.with_deadline).sum();
        let met: u64 = self.classes.iter().map(|c| c.deadline_met).sum();
        if with == 0 {
            1.0
        } else {
            met as f64 / with as f64
        }
    }

    /// Block efficiency: mean tokens emitted per sequence per target
    /// invocation (the paper's BE).
    pub fn block_efficiency(&self) -> f64 {
        if self.seq_rounds == 0 {
            0.0
        } else {
            self.tokens_out as f64 / self.seq_rounds as f64
        }
    }

    /// Draft-token acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Tokens per second of busy time (per-busy-second rate; flat in
    /// replica count after a merge — see [`MetricsSnapshot::merge`]).
    pub fn throughput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.busy_time
        }
    }

    /// Goodput: completed output tokens per second of busy time.
    pub fn goodput(&self) -> f64 {
        if self.busy_time <= 0.0 {
            0.0
        } else {
            self.completed_tokens as f64 / self.busy_time
        }
    }

    /// Mean end-to-end request latency (all-time aggregate).
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// Fold another snapshot into this one — the router's cross-replica
    /// aggregation.  Counters add, clocks take the max, Welford
    /// distributions merge exactly (Chan et al.), and `busy_time` sums to
    /// *total* busy seconds (so the merged [`MetricsSnapshot::throughput`]
    /// is a per-busy-second rate; divide token totals by the makespan for
    /// fleet throughput).
    ///
    /// Percentiles cannot be merged exactly from reduced form: the merged
    /// quantile pairs take the **maximum** across replicas — a conservative
    /// tail estimate that never under-reports the worst replica, so
    /// alerting on the merged `p99_*` keys cannot miss a single-replica
    /// SLO breach (central quantiles are biased toward the slowest
    /// replica).  Callers needing exact fleet percentiles should read the
    /// per-replica values instead.  Both sides must have been produced
    /// with the same requested quantile list.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        let wa = self.window_len;
        let wb = other.window_len;
        self.steps += other.steps;
        self.verify_rounds += other.verify_rounds;
        self.ar_rounds += other.ar_rounds;
        self.seq_rounds += other.seq_rounds;
        self.tokens_out += other.tokens_out;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.straggler_bubble += other.straggler_bubble;
        self.admitted += other.admitted;
        self.preemptions += other.preemptions;
        self.cap_savings += other.cap_savings;
        self.busy_time += other.busy_time;
        self.now = self.now.max(other.now);
        self.batch_hist.merge(&other.batch_hist);
        self.sl_hist.merge(&other.sl_hist);
        self.completed += other.completed;
        self.completed_tokens += other.completed_tokens;
        self.latency.merge(&other.latency);
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        merge_quantiles(&mut self.latency_quantiles, wa, &other.latency_quantiles, wb);
        merge_quantiles(&mut self.ttft_quantiles, wa, &other.ttft_quantiles, wb);
        self.window_len += other.window_len;
        self.window_evicted += other.window_evicted;
        for (c, o) in self.classes.iter_mut().zip(&other.classes) {
            c.merge(o);
        }
        merge_tenants(&mut self.tenants, &other.tenants);
        self.deadline_clamps += other.deadline_clamps;
    }

    /// Serialize with the same core keys as [`EngineMetrics::to_json`] plus
    /// one `p<q>_latency` / `p<q>_ttft` key per requested quantile.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("steps", self.steps)
            .set("verify_rounds", self.verify_rounds)
            .set("ar_rounds", self.ar_rounds)
            .set("tokens_out", self.tokens_out)
            .set("drafted", self.drafted)
            .set("accepted", self.accepted)
            .set("admitted", self.admitted)
            .set("preemptions", self.preemptions)
            .set("cap_savings", self.cap_savings)
            .set("acceptance_rate", self.acceptance_rate())
            .set("block_efficiency", self.block_efficiency())
            .set("throughput", self.throughput())
            .set("goodput", self.goodput())
            .set("mean_latency", self.mean_latency())
            .set("mean_ttft", self.ttft.mean())
            .set("mean_itl", self.itl.mean())
            .set("straggler_bubble", self.straggler_bubble)
            .set("busy_time", self.busy_time)
            .set("requests", self.completed)
            .set("window_requests", self.window_len)
            .set("window_evicted", self.window_evicted)
            .set("slo_attainment", self.slo_attainment())
            .set("deadline_clamps", self.deadline_clamps)
            .set("slo", classes_json(&self.classes))
            .set("tenants", tenants_json(&self.tenants, self.busy_time));
        for &(q, v) in &self.latency_quantiles {
            j = j.set(&quantile_key("latency", q), v);
        }
        for &(q, v) in &self.ttft_quantiles {
            j = j.set(&quantile_key("ttft", q), v);
        }
        j
    }
}

/// Merge matching `(quantile, value)` pair lists by taking the per-quantile
/// maximum across replicas (the conservative estimate documented on
/// [`MetricsSnapshot::merge`]).  Empty windows contribute nothing.
fn merge_quantiles(a: &mut Vec<(f64, f64)>, wa: u64, b: &[(f64, f64)], wb: u64) {
    if wb == 0 || b.is_empty() {
        return;
    }
    if wa == 0 || a.is_empty() {
        *a = b.to_vec();
        return;
    }
    debug_assert_eq!(a.len(), b.len(), "quantile lists must match to merge");
    for ((qa, va), &(qb, vb)) in a.iter_mut().zip(b) {
        debug_assert!((*qa - qb).abs() < 1e-12, "quantile order mismatch");
        let _ = qb;
        *va = va.max(vb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(lat: f64, toks: usize) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            latency: lat,
            ttft: lat * 0.1,
            itl: lat * 0.05,
            output_tokens: toks,
            rounds: 10,
            drafted: 30,
            accepted: 20,
            preemptions: 0,
            tenant: String::new(),
            class: PriorityClass::Standard,
            deadline_met: None,
        }
    }

    fn classed_req(
        lat: f64,
        toks: usize,
        tenant: &str,
        class: PriorityClass,
        deadline_met: Option<bool>,
    ) -> RequestMetrics {
        let mut r = req(lat, toks);
        r.tenant = tenant.to_string();
        r.class = class;
        r.deadline_met = deadline_met;
        r
    }

    #[test]
    fn block_efficiency_math() {
        let mut m = EngineMetrics::default();
        m.verify_rounds = 10;
        m.seq_rounds = 10;
        m.tokens_out = 38;
        assert!((m.block_efficiency() - 3.8).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.block_efficiency(), 0.0);
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.goodput(), 0.0);
    }

    #[test]
    fn latency_aggregation() {
        let mut m = EngineMetrics::default();
        m.record_request(req(2.0, 10));
        m.record_request(req(4.0, 30));
        assert!((m.mean_latency() - 3.0).abs() < 1e-12);
        m.busy_time = 10.0;
        assert!((m.goodput() - 4.0).abs() < 1e-12);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn retention_window_bounds_memory_but_keeps_aggregates() {
        let mut m = EngineMetrics::with_retention(8);
        for i in 0..100 {
            m.record_request(req(1.0 + i as f64, 5));
        }
        // window bounded ...
        assert_eq!(m.requests.len(), 8);
        assert_eq!(m.requests.evicted(), 92);
        // ... while the all-time aggregates still see every request
        assert_eq!(m.completed, 100);
        assert_eq!(m.completed_tokens, 500);
        assert_eq!(m.latency.count(), 100);
        let expect_mean = (0..100).map(|i| 1.0 + i as f64).sum::<f64>() / 100.0;
        assert!((m.mean_latency() - expect_mean).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_counters_and_distributions() {
        let mut a = EngineMetrics::default();
        a.steps = 10;
        a.tokens_out = 100;
        a.admitted = 4;
        a.preemptions = 1;
        a.cap_savings = 7;
        a.busy_time = 2.0;
        a.now = 5.0;
        a.record_request(req(2.0, 10));
        let mut b = EngineMetrics::default();
        b.steps = 20;
        b.tokens_out = 50;
        b.admitted = 6;
        b.preemptions = 2;
        b.cap_savings = 3;
        b.busy_time = 3.0;
        b.now = 4.0;
        b.record_request(req(4.0, 20));
        a.merge(&b);
        assert_eq!(a.steps, 30);
        assert_eq!(a.tokens_out, 150);
        assert_eq!(a.admitted, 10);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.cap_savings, 10);
        assert!((a.busy_time - 5.0).abs() < 1e-12);
        assert!((a.now - 5.0).abs() < 1e-12);
        assert_eq!(a.completed, 2);
        assert!((a.mean_latency() - 3.0).abs() < 1e-12);
        assert_eq!(a.requests.len(), 2);
    }

    #[test]
    fn json_contains_core_fields() {
        let m = EngineMetrics::default();
        let s = m.to_json().to_string();
        assert!(s.contains("block_efficiency"));
        assert!(s.contains("straggler_bubble"));
        assert!(s.contains("admitted"));
        assert!(s.contains("preemptions"));
        assert!(s.contains("cap_savings"));
        assert!(s.contains("window_requests"));
        assert!(s.contains("mean_itl"));
    }

    #[test]
    fn itl_excludes_single_token_requests() {
        let mut m = EngineMetrics::default();
        m.record_request(req(2.0, 1)); // single token: no defined ITL
        m.record_request(req(4.0, 10));
        assert_eq!(m.itl.count(), 1);
        assert!((m.itl.mean() - 0.2).abs() < 1e-12);
        // latency/ttft still see both
        assert_eq!(m.latency.count(), 2);
        assert_eq!(m.ttft.count(), 2);
    }

    #[test]
    fn snapshot_reduces_without_window() {
        let mut m = EngineMetrics::with_retention(16);
        m.busy_time = 10.0;
        m.tokens_out = 40;
        m.seq_rounds = 10;
        for i in 0..10 {
            m.record_request(req(1.0 + i as f64, 4));
        }
        let s = m.snapshot(&[0.5, 0.99]);
        assert_eq!(s.completed, 10);
        assert_eq!(s.window_len, 10);
        assert_eq!(s.latency_quantiles.len(), 2);
        assert_eq!(s.latency_quantiles[0].0, 0.5);
        assert!((s.latency_quantiles[0].1 - 5.5).abs() < 1e-9);
        assert!((s.mean_latency() - m.mean_latency()).abs() < 1e-12);
        assert!((s.block_efficiency() - m.block_efficiency()).abs() < 1e-12);
        assert!((s.throughput() - m.throughput()).abs() < 1e-12);
        let js = s.to_json().to_string();
        assert!(js.contains("\"p50_latency\":"), "{js}");
        assert!(js.contains("\"p99_latency\":"), "{js}");
        assert!(js.contains("\"p50_ttft\":"), "{js}");
    }

    #[test]
    fn snapshot_merge_sums_counters_and_maxes_quantiles() {
        let mut a = EngineMetrics::default();
        a.tokens_out = 100;
        a.busy_time = 2.0;
        a.record_request(req(2.0, 10));
        let mut b = EngineMetrics::default();
        b.tokens_out = 50;
        b.busy_time = 3.0;
        b.record_request(req(4.0, 20));
        b.record_request(req(6.0, 20));
        let mut sa = a.snapshot(DEFAULT_QUANTILES);
        let sb = b.snapshot(DEFAULT_QUANTILES);
        sa.merge(&sb);
        assert_eq!(sa.tokens_out, 150);
        assert_eq!(sa.completed, 3);
        assert_eq!(sa.window_len, 3);
        assert!((sa.busy_time - 5.0).abs() < 1e-12);
        assert_eq!(sa.latency.count(), 3);
        assert!((sa.mean_latency() - 4.0).abs() < 1e-12);
        // conservative merge: per-quantile max across replicas —
        // max(p50_a = 2.0, p50_b = 5.0) = 5.0, never under the worst replica
        let p50 = sa.latency_quantiles.iter().find(|(q, _)| *q == 0.5).unwrap().1;
        assert!((p50 - 5.0).abs() < 1e-9, "p50 {p50}");
    }

    #[test]
    fn class_rollups_track_attainment_and_tenants() {
        let mut m = EngineMetrics::default();
        m.busy_time = 10.0;
        m.record_request(classed_req(0.1, 10, "a", PriorityClass::Interactive, Some(true)));
        m.record_request(classed_req(0.5, 10, "a", PriorityClass::Interactive, Some(false)));
        m.record_request(classed_req(2.0, 40, "b", PriorityClass::BestEffort, None));
        let icls = &m.classes[PriorityClass::Interactive.rank()];
        assert_eq!(icls.completed, 2);
        assert_eq!(icls.with_deadline, 2);
        assert_eq!(icls.deadline_met, 1);
        assert!((icls.attainment() - 0.5).abs() < 1e-12);
        // best-effort carried no deadline: vacuously attained
        let be = &m.classes[PriorityClass::BestEffort.rank()];
        assert_eq!(be.attainment(), 1.0);
        assert!((m.slo_attainment() - 0.5).abs() < 1e-12);
        assert_eq!(m.tenants["a"].completed, 2);
        assert_eq!(m.tenants["b"].completed_tokens, 40);
        m.record_class_sl(PriorityClass::Interactive, 2);
        m.record_class_sl(PriorityClass::Interactive, 4);
        assert!((m.classes[0].mean_sl() - 3.0).abs() < 1e-12);
        let js = m.to_json().to_string();
        assert!(js.contains("\"slo_attainment\":"), "{js}");
        assert!(js.contains("\"interactive\":"), "{js}");
        assert!(js.contains("\"best-effort\":"), "{js}");
        assert!(js.contains("\"tenants\":"), "{js}");
        assert!(js.contains("\"deadline_clamps\":"), "{js}");
        assert!(js.contains("\"goodput\":"), "{js}");
    }

    #[test]
    fn class_and_tenant_rollups_merge_across_replicas() {
        let mut a = EngineMetrics::default();
        a.deadline_clamps = 2;
        a.record_request(classed_req(0.1, 5, "t", PriorityClass::Interactive, Some(true)));
        let mut b = EngineMetrics::default();
        b.deadline_clamps = 3;
        b.record_request(classed_req(0.2, 7, "t", PriorityClass::Interactive, Some(false)));
        b.record_request(classed_req(0.9, 9, "u", PriorityClass::Standard, None));
        // both the in-process merge and the snapshot (wire) merge agree
        let mut sa = a.snapshot(DEFAULT_QUANTILES);
        sa.merge(&b.snapshot(DEFAULT_QUANTILES));
        a.merge(&b);
        for m in [
            (a.classes, a.tenants.clone(), a.deadline_clamps),
            (sa.classes, sa.tenants.clone(), sa.deadline_clamps),
        ] {
            let (classes, tenants, clamps) = m;
            assert_eq!(classes[0].completed, 2);
            assert_eq!(classes[0].with_deadline, 2);
            assert_eq!(classes[0].deadline_met, 1);
            assert_eq!(classes[1].completed, 1);
            assert_eq!(tenants["t"].completed, 2);
            assert_eq!(tenants["t"].completed_tokens, 12);
            assert_eq!(tenants["u"].completed, 1);
            assert_eq!(clamps, 5);
        }
        assert!((sa.slo_attainment() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn snapshot_merge_with_empty_is_identity() {
        let mut m = EngineMetrics::default();
        m.record_request(req(2.0, 8));
        let mut s = m.snapshot(DEFAULT_QUANTILES);
        let before_p50 = s.latency_quantiles[0].1;
        s.merge(&MetricsSnapshot::default());
        assert_eq!(s.completed, 1);
        assert_eq!(s.latency_quantiles[0].1, before_p50);
        let mut empty = MetricsSnapshot::default();
        empty.merge(&s);
        assert_eq!(empty.completed, 1);
        assert_eq!(empty.latency_quantiles.len(), DEFAULT_QUANTILES.len());
    }
}
