//! The serving engine (vLLM-analog): request/sequence state, paged KV
//! manager, continuous-batching scheduler with per-sequence look-ahead,
//! the staged speculative step pipeline (`plan → execute → apply`), and
//! metrics.

pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod step;
