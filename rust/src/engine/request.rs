//! Requests, sampling parameters, and per-sequence engine state.

use crate::model::vocab;
use crate::spec::history::SeqSignals;

/// Per-request sampling parameters (per-sequence, as the paper's future-work
/// section motivates — each request can carry its own temperature).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Sampling temperature; 0.0 = greedy (or defer to the engine default).
    pub temperature: f64,
    /// stop generation after this many new tokens
    pub max_tokens: usize,
    /// optional stop token (e.g. b'\0'); None = run to max_tokens
    pub stop_token: Option<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_tokens: 64,
            stop_token: None,
        }
    }
}

/// An inference request submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request id; the router overwrites it with a globally unique one.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<u32>,
    /// Per-request sampling parameters.
    pub params: SamplingParams,
    /// submission time on the engine clock (set by the engine at submit)
    pub arrival: f64,
    /// Queue wait already accrued on another replica before a work-steal
    /// migration (engine seconds).  The engine backdates `arrival` by this
    /// much at submit so latency/TTFT keep counting the victim-side wait.
    pub waited: f64,
}

impl Request {
    /// Construct a request from raw token ids.
    pub fn new(id: u64, prompt: Vec<u32>, params: SamplingParams) -> Request {
        Request {
            id,
            prompt,
            params,
            arrival: 0.0,
            waited: 0.0,
        }
    }

    /// Convenience: byte-encode a text prompt.
    pub fn text(id: u64, prompt: &str, max_tokens: usize) -> Request {
        Request::new(
            id,
            vocab::encode(prompt),
            SamplingParams {
                max_tokens,
                ..Default::default()
            },
        )
    }

    /// Builder-style temperature override.
    pub fn with_temperature(mut self, t: f64) -> Request {
        self.params.temperature = t;
        self
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The request's `max_tokens` output budget was produced.
    MaxTokens,
    /// The configured stop token was generated.
    StopToken,
    /// The context window filled up before the budget was met.
    ContextFull,
    /// Aborted by shutdown, client disconnect, or an unservable prompt.
    Aborted,
}

impl FinishReason {
    /// Stable lowercase wire name (HTTP payloads, logs).
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::ContextFull => "context_full",
            FinishReason::Aborted => "aborted",
        }
    }
}

/// Live per-sequence engine state.
#[derive(Clone, Debug)]
pub struct SeqState {
    /// Request id this sequence serves.
    pub id: u64,
    /// Length of the prompt prefix inside [`SeqState::tokens`].
    pub prompt_len: usize,
    /// prompt + generated tokens
    pub tokens: Vec<u32>,
    /// Sampling parameters inherited from the request.
    pub params: SamplingParams,
    /// Online KLD/entropy/acceptance signal history (SL adapter input).
    pub signals: SeqSignals,
    /// Arrival time on the engine clock.
    pub arrival: f64,
    /// Engine-clock time the first output token was applied, if any.
    pub first_token_at: Option<f64>,
    /// engine steps this sequence participated in
    pub rounds: usize,
    /// number of times preempted (KV pressure)
    pub preemptions: usize,
}

impl SeqState {
    /// Initial sequence state for a freshly admitted request.
    pub fn from_request(req: Request) -> SeqState {
        let prompt_len = req.prompt.len();
        SeqState {
            id: req.id,
            prompt_len,
            tokens: req.prompt,
            params: req.params,
            signals: SeqSignals::default(),
            arrival: req.arrival,
            first_token_at: None,
            rounds: 0,
            preemptions: 0,
        }
    }

    /// Output tokens generated so far.
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The generated (non-prompt) token suffix.
    pub fn generated_tokens(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }

    /// Decoded text of the generated tokens.
    pub fn output_text(&self) -> String {
        vocab::decode(self.generated_tokens())
    }

    /// Remaining output budget.
    pub fn remaining(&self) -> usize {
        self.params.max_tokens.saturating_sub(self.generated())
    }

    /// Whether the sequence should retire, and why.
    pub fn is_done(&self, max_len: usize) -> Option<FinishReason> {
        if self.generated() >= self.params.max_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if let Some(stop) = self.params.stop_token {
            if self.generated_tokens().contains(&stop) {
                return Some(FinishReason::StopToken);
            }
        }
        if self.tokens.len() >= max_len.saturating_sub(1) {
            return Some(FinishReason::ContextFull);
        }
        None
    }
}

/// A finished request as returned to callers.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    /// Request id.
    pub id: u64,
    /// Generated output token ids.
    pub output: Vec<u32>,
    /// Why the request finished.
    pub reason: FinishReason,
    /// Arrival time on the engine clock.
    pub arrival: f64,
    /// Engine-clock time the request retired.
    pub finished_at: f64,
    /// Engine-clock time the first output token was applied.
    pub first_token_at: f64,
    /// Engine rounds the request participated in.
    pub rounds: usize,
    /// Draft tokens proposed for this request.
    pub drafted: u64,
    /// Draft tokens accepted for this request.
    pub accepted: u64,
    /// Times the request was preempted under KV pressure.
    pub preemptions: usize,
}

impl FinishedRequest {
    /// End-to-end latency in engine seconds.
    pub fn latency(&self) -> f64 {
        self.finished_at - self.arrival
    }

    /// Time to first token in engine seconds.
    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }

    /// Mean inter-token latency in engine seconds: the decode tail
    /// (first token → finish) averaged over the remaining tokens.
    /// 0.0 when fewer than two output tokens were produced.
    pub fn itl(&self) -> f64 {
        if self.output.len() < 2 {
            0.0
        } else {
            (self.finished_at - self.first_token_at) / (self.output.len() - 1) as f64
        }
    }

    /// Decoded output text.
    pub fn output_text(&self) -> String {
        vocab::decode(&self.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_request_encodes_prompt() {
        let r = Request::text(1, "ab", 8);
        assert_eq!(r.prompt, vec![97, 98]);
        assert_eq!(r.params.max_tokens, 8);
    }

    #[test]
    fn seqstate_counts_generated() {
        let mut s = SeqState::from_request(Request::text(1, "abc", 4));
        assert_eq!(s.generated(), 0);
        s.tokens.push(120);
        s.tokens.push(121);
        assert_eq!(s.generated(), 2);
        assert_eq!(s.output_text(), "xy");
        assert_eq!(s.remaining(), 2);
    }

    #[test]
    fn finish_on_max_tokens() {
        let mut s = SeqState::from_request(Request::text(1, "a", 2));
        assert!(s.is_done(100).is_none());
        s.tokens.push(65);
        s.tokens.push(66);
        assert_eq!(s.is_done(100), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn finish_on_stop_token() {
        let mut req = Request::text(1, "a", 100);
        req.params.stop_token = Some(10);
        let mut s = SeqState::from_request(req);
        s.tokens.push(65);
        assert!(s.is_done(100).is_none());
        s.tokens.push(10);
        assert_eq!(s.is_done(100), Some(FinishReason::StopToken));
    }

    #[test]
    fn finish_on_context_full() {
        let mut s = SeqState::from_request(Request::text(1, "abcd", 100));
        s.tokens.extend([65; 4]);
        assert_eq!(s.is_done(9), Some(FinishReason::ContextFull));
        assert!(s.is_done(100).is_none());
    }

    #[test]
    fn finished_latency_math() {
        let f = FinishedRequest {
            id: 1,
            output: vec![104, 105],
            reason: FinishReason::MaxTokens,
            arrival: 2.0,
            finished_at: 5.5,
            first_token_at: 2.5,
            rounds: 3,
            drafted: 10,
            accepted: 7,
            preemptions: 0,
        };
        assert!((f.latency() - 3.5).abs() < 1e-12);
        assert!((f.ttft() - 0.5).abs() < 1e-12);
        // two output tokens: ITL spreads first-token -> finish over 1 gap
        assert!((f.itl() - 3.0).abs() < 1e-12);
        assert_eq!(f.output_text(), "hi");
    }

    #[test]
    fn itl_zero_for_single_token() {
        let f = FinishedRequest {
            id: 1,
            output: vec![104],
            reason: FinishReason::MaxTokens,
            arrival: 0.0,
            finished_at: 1.0,
            first_token_at: 1.0,
            rounds: 1,
            drafted: 0,
            accepted: 0,
            preemptions: 0,
        };
        assert_eq!(f.itl(), 0.0);
    }

    #[test]
    fn finish_reason_wire_names() {
        assert_eq!(FinishReason::MaxTokens.name(), "max_tokens");
        assert_eq!(FinishReason::StopToken.name(), "stop_token");
        assert_eq!(FinishReason::ContextFull.name(), "context_full");
        assert_eq!(FinishReason::Aborted.name(), "aborted");
    }
}
