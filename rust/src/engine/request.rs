//! Requests, sampling parameters, and per-sequence engine state.

use crate::model::vocab;
use crate::spec::history::SeqSignals;

/// Per-request sampling parameters (per-sequence, as the paper's future-work
/// section motivates — each request can carry its own temperature).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Sampling temperature; 0.0 = greedy (or defer to the engine default).
    pub temperature: f64,
    /// stop generation after this many new tokens
    pub max_tokens: usize,
    /// optional stop token (e.g. b'\0'); None = run to max_tokens
    pub stop_token: Option<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_tokens: 64,
            stop_token: None,
        }
    }
}

/// Scheduling priority class of a request (multi-tenant serving).
///
/// Classes order strict-priority admission: interactive ahead of standard
/// ahead of best-effort, with an aging escape hatch in the scheduler so
/// best-effort work is never starved (see
/// [`crate::engine::scheduler::Scheduler::admit_prioritized`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PriorityClass {
    /// Latency-sensitive interactive traffic: admitted first and allowed
    /// to preempt running best-effort work under pressure.
    Interactive,
    /// The default class — FCFS among itself, behind interactive.
    #[default]
    Standard,
    /// Throughput batch work: admitted when higher classes leave room,
    /// protected from starvation by queue-age escalation.
    BestEffort,
}

impl PriorityClass {
    /// All classes in admission-rank order (interactive first).
    pub const ALL: [PriorityClass; 3] = [
        PriorityClass::Interactive,
        PriorityClass::Standard,
        PriorityClass::BestEffort,
    ];

    /// Parse CLI/header shorthand: `interactive`, `standard`, or
    /// `best-effort` (also `besteffort`/`batch`).
    pub fn parse(s: &str) -> Option<PriorityClass> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(PriorityClass::Interactive),
            "standard" | "default" => Some(PriorityClass::Standard),
            "best-effort" | "besteffort" | "best_effort" | "batch" => {
                Some(PriorityClass::BestEffort)
            }
            _ => None,
        }
    }

    /// Stable lowercase wire/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            PriorityClass::Interactive => "interactive",
            PriorityClass::Standard => "standard",
            PriorityClass::BestEffort => "best-effort",
        }
    }

    /// Admission rank: lower admits first (0 = interactive).
    pub fn rank(&self) -> usize {
        match self {
            PriorityClass::Interactive => 0,
            PriorityClass::Standard => 1,
            PriorityClass::BestEffort => 2,
        }
    }
}

/// An inference request submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request id; the router overwrites it with a globally unique one.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<u32>,
    /// Per-request sampling parameters.
    pub params: SamplingParams,
    /// submission time on the engine clock (set by the engine at submit)
    pub arrival: f64,
    /// Queue wait already accrued on another replica before a work-steal
    /// migration (engine seconds).  The engine backdates `arrival` by this
    /// much at submit so latency/TTFT keep counting the victim-side wait.
    pub waited: f64,
    /// Tenant identifier for per-tenant accounting and rate limiting
    /// (empty = unattributed; the pre-tenancy wire format).
    pub tenant: String,
    /// Scheduling priority class (defaults to [`PriorityClass::Standard`]).
    pub class: PriorityClass,
    /// Optional end-to-end latency deadline in milliseconds, measured from
    /// arrival.  Drives per-class SLO-attainment metrics and the
    /// deadline-slack SL clamp ([`crate::spec::cap::apply_deadline_slack`]).
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// Construct a request from raw token ids.
    pub fn new(id: u64, prompt: Vec<u32>, params: SamplingParams) -> Request {
        Request {
            id,
            prompt,
            params,
            arrival: 0.0,
            waited: 0.0,
            tenant: String::new(),
            class: PriorityClass::Standard,
            deadline_ms: None,
        }
    }

    /// Convenience: byte-encode a text prompt.
    pub fn text(id: u64, prompt: &str, max_tokens: usize) -> Request {
        Request::new(
            id,
            vocab::encode(prompt),
            SamplingParams {
                max_tokens,
                ..Default::default()
            },
        )
    }

    /// Builder-style temperature override.
    pub fn with_temperature(mut self, t: f64) -> Request {
        self.params.temperature = t;
        self
    }

    /// Builder-style tenancy attribution: tenant name, priority class, and
    /// optional deadline in one call (the serving/front-end path).
    pub fn with_tenancy(
        mut self,
        tenant: &str,
        class: PriorityClass,
        deadline_ms: Option<u64>,
    ) -> Request {
        self.tenant = tenant.to_string();
        self.class = class;
        self.deadline_ms = deadline_ms;
        self
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The request's `max_tokens` output budget was produced.
    MaxTokens,
    /// The configured stop token was generated.
    StopToken,
    /// The context window filled up before the budget was met.
    ContextFull,
    /// Aborted by shutdown, client disconnect, or an unservable prompt.
    Aborted,
}

impl FinishReason {
    /// Stable lowercase wire name (HTTP payloads, logs).
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::ContextFull => "context_full",
            FinishReason::Aborted => "aborted",
        }
    }
}

/// Live per-sequence engine state.
#[derive(Clone, Debug)]
pub struct SeqState {
    /// Request id this sequence serves.
    pub id: u64,
    /// Length of the prompt prefix inside [`SeqState::tokens`].
    pub prompt_len: usize,
    /// prompt + generated tokens
    pub tokens: Vec<u32>,
    /// Sampling parameters inherited from the request.
    pub params: SamplingParams,
    /// Online KLD/entropy/acceptance signal history (SL adapter input).
    pub signals: SeqSignals,
    /// Arrival time on the engine clock.
    pub arrival: f64,
    /// Engine-clock time the first output token was applied, if any.
    pub first_token_at: Option<f64>,
    /// engine steps this sequence participated in
    pub rounds: usize,
    /// number of times preempted (KV pressure)
    pub preemptions: usize,
    /// Tenant identifier inherited from the request ("" = unattributed).
    pub tenant: String,
    /// Scheduling priority class inherited from the request.
    pub class: PriorityClass,
    /// Optional end-to-end deadline in milliseconds from arrival.
    pub deadline_ms: Option<u64>,
}

impl SeqState {
    /// Initial sequence state for a freshly admitted request.
    pub fn from_request(req: Request) -> SeqState {
        let prompt_len = req.prompt.len();
        SeqState {
            id: req.id,
            prompt_len,
            tokens: req.prompt,
            params: req.params,
            signals: SeqSignals::default(),
            arrival: req.arrival,
            first_token_at: None,
            rounds: 0,
            preemptions: 0,
            tenant: req.tenant,
            class: req.class,
            deadline_ms: req.deadline_ms,
        }
    }

    /// Fraction of the deadline budget still unspent at engine time `now`:
    /// `1.0` = the whole budget remains, `0.0` or negative = the deadline
    /// has passed.  `None` when the request carries no deadline — the
    /// deadline-slack SL clamp is a strict no-op for such sequences.
    pub fn deadline_slack_frac(&self, now: f64) -> Option<f64> {
        self.deadline_ms.map(|d| {
            let total = (d as f64 / 1000.0).max(1e-9);
            let elapsed = (now - self.arrival).max(0.0);
            1.0 - elapsed / total
        })
    }

    /// Output tokens generated so far.
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The generated (non-prompt) token suffix.
    pub fn generated_tokens(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }

    /// Decoded text of the generated tokens.
    pub fn output_text(&self) -> String {
        vocab::decode(self.generated_tokens())
    }

    /// Remaining output budget.
    pub fn remaining(&self) -> usize {
        self.params.max_tokens.saturating_sub(self.generated())
    }

    /// Whether the sequence should retire, and why.
    pub fn is_done(&self, max_len: usize) -> Option<FinishReason> {
        if self.generated() >= self.params.max_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if let Some(stop) = self.params.stop_token {
            if self.generated_tokens().contains(&stop) {
                return Some(FinishReason::StopToken);
            }
        }
        if self.tokens.len() >= max_len.saturating_sub(1) {
            return Some(FinishReason::ContextFull);
        }
        None
    }
}

/// A finished request as returned to callers.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    /// Request id.
    pub id: u64,
    /// Generated output token ids.
    pub output: Vec<u32>,
    /// Why the request finished.
    pub reason: FinishReason,
    /// Arrival time on the engine clock.
    pub arrival: f64,
    /// Engine-clock time the request retired.
    pub finished_at: f64,
    /// Engine-clock time the first output token was applied.
    pub first_token_at: f64,
    /// Engine rounds the request participated in.
    pub rounds: usize,
    /// Draft tokens proposed for this request.
    pub drafted: u64,
    /// Draft tokens accepted for this request.
    pub accepted: u64,
    /// Times the request was preempted under KV pressure.
    pub preemptions: usize,
    /// Tenant identifier inherited from the request ("" = unattributed).
    pub tenant: String,
    /// Scheduling priority class inherited from the request.
    pub class: PriorityClass,
    /// Optional end-to-end deadline in milliseconds from arrival.
    pub deadline_ms: Option<u64>,
}

impl FinishedRequest {
    /// Whether the request finished within its deadline; `None` when it
    /// carried no deadline (such requests never count against SLO
    /// attainment).
    pub fn deadline_met(&self) -> Option<bool> {
        self.deadline_ms
            .map(|d| self.latency() * 1000.0 <= d as f64)
    }

    /// End-to-end latency in engine seconds.
    pub fn latency(&self) -> f64 {
        self.finished_at - self.arrival
    }

    /// Time to first token in engine seconds.
    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }

    /// Mean inter-token latency in engine seconds: the decode tail
    /// (first token → finish) averaged over the remaining tokens.
    /// 0.0 when fewer than two output tokens were produced.
    pub fn itl(&self) -> f64 {
        if self.output.len() < 2 {
            0.0
        } else {
            (self.finished_at - self.first_token_at) / (self.output.len() - 1) as f64
        }
    }

    /// Decoded output text.
    pub fn output_text(&self) -> String {
        vocab::decode(&self.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_request_encodes_prompt() {
        let r = Request::text(1, "ab", 8);
        assert_eq!(r.prompt, vec![97, 98]);
        assert_eq!(r.params.max_tokens, 8);
    }

    #[test]
    fn seqstate_counts_generated() {
        let mut s = SeqState::from_request(Request::text(1, "abc", 4));
        assert_eq!(s.generated(), 0);
        s.tokens.push(120);
        s.tokens.push(121);
        assert_eq!(s.generated(), 2);
        assert_eq!(s.output_text(), "xy");
        assert_eq!(s.remaining(), 2);
    }

    #[test]
    fn finish_on_max_tokens() {
        let mut s = SeqState::from_request(Request::text(1, "a", 2));
        assert!(s.is_done(100).is_none());
        s.tokens.push(65);
        s.tokens.push(66);
        assert_eq!(s.is_done(100), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn finish_on_stop_token() {
        let mut req = Request::text(1, "a", 100);
        req.params.stop_token = Some(10);
        let mut s = SeqState::from_request(req);
        s.tokens.push(65);
        assert!(s.is_done(100).is_none());
        s.tokens.push(10);
        assert_eq!(s.is_done(100), Some(FinishReason::StopToken));
    }

    #[test]
    fn finish_on_context_full() {
        let mut s = SeqState::from_request(Request::text(1, "abcd", 100));
        s.tokens.extend([65; 4]);
        assert_eq!(s.is_done(9), Some(FinishReason::ContextFull));
        assert!(s.is_done(100).is_none());
    }

    #[test]
    fn finished_latency_math() {
        let f = FinishedRequest {
            id: 1,
            output: vec![104, 105],
            reason: FinishReason::MaxTokens,
            arrival: 2.0,
            finished_at: 5.5,
            first_token_at: 2.5,
            rounds: 3,
            drafted: 10,
            accepted: 7,
            preemptions: 0,
            tenant: String::new(),
            class: PriorityClass::Standard,
            deadline_ms: None,
        };
        assert!((f.latency() - 3.5).abs() < 1e-12);
        assert!((f.ttft() - 0.5).abs() < 1e-12);
        // two output tokens: ITL spreads first-token -> finish over 1 gap
        assert!((f.itl() - 3.0).abs() < 1e-12);
        assert_eq!(f.output_text(), "hi");
    }

    #[test]
    fn itl_zero_for_single_token() {
        let f = FinishedRequest {
            id: 1,
            output: vec![104],
            reason: FinishReason::MaxTokens,
            arrival: 0.0,
            finished_at: 1.0,
            first_token_at: 1.0,
            rounds: 1,
            drafted: 0,
            accepted: 0,
            preemptions: 0,
            tenant: String::new(),
            class: PriorityClass::Standard,
            deadline_ms: None,
        };
        assert_eq!(f.itl(), 0.0);
    }

    #[test]
    fn priority_class_parse_roundtrip() {
        for c in PriorityClass::ALL {
            assert_eq!(PriorityClass::parse(c.name()), Some(c));
        }
        assert_eq!(
            PriorityClass::parse("BATCH"),
            Some(PriorityClass::BestEffort)
        );
        assert_eq!(PriorityClass::parse("nope"), None);
        assert_eq!(PriorityClass::default(), PriorityClass::Standard);
        assert_eq!(PriorityClass::Interactive.rank(), 0);
        assert_eq!(PriorityClass::BestEffort.rank(), 2);
    }

    #[test]
    fn tenancy_rides_request_to_seqstate_and_finish() {
        let req = Request::text(4, "hello", 8).with_tenancy(
            "acme",
            PriorityClass::Interactive,
            Some(250),
        );
        let s = SeqState::from_request(req);
        assert_eq!(s.tenant, "acme");
        assert_eq!(s.class, PriorityClass::Interactive);
        assert_eq!(s.deadline_ms, Some(250));
        // half the 250 ms budget spent at t = 0.125 (arrival 0)
        let frac = s.deadline_slack_frac(0.125).unwrap();
        assert!((frac - 0.5).abs() < 1e-9, "{frac}");
        assert!(s.deadline_slack_frac(1.0).unwrap() < 0.0, "past deadline");
        let plain = SeqState::from_request(Request::text(5, "x", 4));
        assert_eq!(plain.deadline_slack_frac(100.0), None);
    }

    #[test]
    fn deadline_met_accounting() {
        let mut f = FinishedRequest {
            id: 1,
            output: vec![104],
            reason: FinishReason::MaxTokens,
            arrival: 0.0,
            finished_at: 0.2,
            first_token_at: 0.1,
            rounds: 1,
            drafted: 0,
            accepted: 0,
            preemptions: 0,
            tenant: "t".to_string(),
            class: PriorityClass::Interactive,
            deadline_ms: Some(250),
        };
        assert_eq!(f.deadline_met(), Some(true)); // 200 ms <= 250 ms
        f.finished_at = 0.3;
        assert_eq!(f.deadline_met(), Some(false));
        f.deadline_ms = None;
        assert_eq!(f.deadline_met(), None);
    }

    #[test]
    fn finish_reason_wire_names() {
        assert_eq!(FinishReason::MaxTokens.name(), "max_tokens");
        assert_eq!(FinishReason::StopToken.name(), "stop_token");
        assert_eq!(FinishReason::ContextFull.name(), "context_full");
        assert_eq!(FinishReason::Aborted.name(), "aborted");
    }
}
