//! Workload generation: the paper's eight evaluation datasets as synthetic
//! request generators (prompt text of the right task flavor + length and
//! output-length distributions from the dataset profile), plus arrival
//! processes for open-loop serving experiments.

use crate::engine::request::{PriorityClass, Request, SamplingParams};
use crate::model::vocab;
use crate::sim::regime::DatasetProfile;
use crate::util::rng::Rng;

/// A named dataset workload.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Length/acceptance profile backing this dataset's simulator regime.
    pub profile: DatasetProfile,
}

impl Dataset {
    /// Look up one of the paper's eight datasets by name (e.g. `cnndm`).
    pub fn by_name(name: &str) -> Option<Dataset> {
        DatasetProfile::by_name(name).map(|profile| Dataset { profile })
    }

    /// All eight evaluation datasets.
    pub fn all() -> Vec<Dataset> {
        DatasetProfile::all()
            .into_iter()
            .map(|profile| Dataset { profile })
            .collect()
    }

    /// The dataset's stable name.
    pub fn name(&self) -> &'static str {
        self.profile.name
    }

    /// Task flavor used for prompt text synthesis.
    fn flavor(&self) -> &'static str {
        match self.profile.name {
            "humaneval" => "code",
            "sharegpt" => "dialogue",
            "gsm8k" => "math",
            _ => "prose",
        }
    }
}

/// Deterministic request generator over a dataset.
pub struct WorkloadGen {
    dataset: Dataset,
    rng: Rng,
    next_id: u64,
    temperature: f64,
    /// clamp on generated output length (e.g. context budget of the tiny
    /// PJRT model); usize::MAX = profile-driven only
    pub max_output: usize,
    /// clamp on generated prompt length; usize::MAX = profile-driven only
    pub max_prompt: usize,
}

impl WorkloadGen {
    /// Deterministic generator over `dataset`, seeded for reproducibility.
    pub fn new(dataset: Dataset, seed: u64) -> WorkloadGen {
        WorkloadGen {
            dataset,
            rng: Rng::new(seed),
            next_id: 0,
            temperature: 0.0,
            max_output: usize::MAX,
            max_prompt: usize::MAX,
        }
    }

    /// Builder-style sampling temperature for the generated requests.
    pub fn with_temperature(mut self, t: f64) -> WorkloadGen {
        self.temperature = t;
        self
    }

    /// Constrain lengths (used by the PJRT path whose context is 160, and
    /// by eval-grid cells).  Profile-drawn lengths are **clamped** into the
    /// limits — never rejected — so even a limit below the generator's
    /// natural floor (prompt 8 / output 4) yields requests that honor it
    /// (down to 1 token) instead of silently exceeding it and stalling a
    /// low-`max_output` grid cell.
    pub fn with_limits(mut self, max_prompt: usize, max_output: usize) -> WorkloadGen {
        self.max_prompt = max_prompt;
        self.max_output = max_output;
        self
    }

    /// The dataset this generator draws from.
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// Synthesize one request.
    pub fn next_request(&mut self) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let p = &self.dataset.profile;
        // lengths: lognormal-ish around the profile means, clamped into the
        // caller's limits (floors shrink below the defaults of 8/4 when the
        // limit itself is smaller — the limit always wins)
        let max_prompt = self.max_prompt.max(1);
        let max_output = self.max_output.max(1);
        let plen = ((p.mean_prompt as f64) * (0.6 + 0.8 * self.rng.f64())) as usize;
        let plen = plen.clamp(8.min(max_prompt), max_prompt);
        let olen = ((p.mean_output as f64) * (0.6 + 0.8 * self.rng.f64())) as usize;
        let olen = olen.clamp(4.min(max_output), max_output);
        let prompt = self.prompt_text(plen);
        Request::new(
            id,
            prompt,
            SamplingParams {
                temperature: self.temperature,
                max_tokens: olen,
                stop_token: None,
            },
        )
    }

    /// A batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    fn prompt_text(&mut self, len: usize) -> Vec<u32> {
        let text = match self.dataset.flavor() {
            "code" => Self::code_prompt(&mut self.rng),
            "dialogue" => Self::dialogue_prompt(&mut self.rng),
            "math" => Self::math_prompt(&mut self.rng),
            _ => Self::prose_prompt(&mut self.rng),
        };
        let mut toks = vocab::encode(&text);
        toks.truncate(len);
        while toks.len() < len {
            toks.push(b' ' as u32);
        }
        toks
    }

    fn code_prompt(rng: &mut Rng) -> String {
        let fns = ["compute", "process", "merge", "scan", "reduce"];
        let vars = ["count", "total", "idx", "value", "acc"];
        format!(
            "def {}({}):\n    {} = 0\n    for {} in range({}):\n        ",
            fns[rng.range(0, fns.len())],
            vars[rng.range(0, vars.len())],
            vars[rng.range(0, vars.len())],
            vars[rng.range(0, vars.len())],
            rng.range(2, 64)
        )
    }

    fn dialogue_prompt(rng: &mut Rng) -> String {
        let topics = [
            "the overall cost",
            "a new method",
            "daily traffic",
            "the main problem",
            "future growth",
        ];
        format!(
            "User: Can you explain {} in simple terms?\nAgent: ",
            topics[rng.range(0, topics.len())]
        )
    }

    fn math_prompt(rng: &mut Rng) -> String {
        format!(
            "Q: A box holds {} items and another holds {} items. Each item \
             costs {}. What is the total cost?\nA: ",
            rng.range(2, 40),
            rng.range(2, 40),
            rng.range(2, 12)
        )
    }

    fn prose_prompt(rng: &mut Rng) -> String {
        let subjects = ["The system", "A model", "The report", "The market"];
        format!(
            "{} shows the results clearly. Summarize: ",
            subjects[rng.range(0, subjects.len())]
        )
    }
}

/// Anything that can synthesize a stream of requests — implemented by the
/// single-dataset [`WorkloadGen`] and the multi-tenant [`MixedWorkloadGen`]
/// so grid cells and trace synthesis can hold either behind one object.
pub trait RequestSource {
    /// Synthesize the next request.
    fn next_request(&mut self) -> Request;

    /// A batch of `n` requests.
    fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| RequestSource::next_request(self)).collect()
    }
}

impl RequestSource for WorkloadGen {
    fn next_request(&mut self) -> Request {
        WorkloadGen::next_request(self)
    }
}

/// Weighted mixture of dataset workloads — the multi-tenant traffic shape
/// the eval grid sweeps (heterogeneous large-batch serving mixes several
/// task types in one continuous batch).  Each component keeps its own
/// deterministic [`WorkloadGen`] stream; the mixture draws the component
/// per request by weight, so a mix is as reproducible as its seed.
pub struct MixedWorkloadGen {
    components: Vec<WorkloadGen>,
    weights: Vec<f64>,
    rng: Rng,
    base_seed: u64,
    next_id: u64,
}

impl MixedWorkloadGen {
    /// An empty mixture (add components with
    /// [`MixedWorkloadGen::with_component`]).
    pub fn new(seed: u64) -> MixedWorkloadGen {
        MixedWorkloadGen {
            components: Vec::new(),
            weights: Vec::new(),
            rng: Rng::new(seed ^ 0x4D49_5845), // "MIXE"
            base_seed: seed,
            next_id: 0,
        }
    }

    /// Add a dataset with a positive selection weight.
    pub fn with_component(mut self, dataset: Dataset, weight: f64) -> MixedWorkloadGen {
        assert!(weight > 0.0, "mix weight must be positive");
        let idx = self.components.len() as u64 + 1;
        let seed = self.base_seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.components.push(WorkloadGen::new(dataset, seed));
        self.weights.push(weight);
        self
    }

    /// Parse a mix spec like `"sharegpt=2+humaneval=1"` (weights default to
    /// 1 when omitted, components separated by `+` or `,`).  Returns `None`
    /// on an unknown dataset, a non-positive weight, or an empty spec.
    pub fn parse(spec: &str, seed: u64) -> Option<MixedWorkloadGen> {
        let mut mix = MixedWorkloadGen::new(seed);
        for part in spec.split(['+', ',']).filter(|p| !p.trim().is_empty()) {
            let (name, weight) = match part.split_once('=') {
                Some((n, w)) => (n.trim(), w.trim().parse::<f64>().ok()?),
                None => (part.trim(), 1.0),
            };
            // NaN must fail parsing too, not reach the constructor
            // assert (or the categorical draw)
            if weight <= 0.0 || weight.is_nan() {
                return None;
            }
            mix = mix.with_component(Dataset::by_name(name)?, weight);
        }
        if mix.components.is_empty() {
            None
        } else {
            Some(mix)
        }
    }

    /// Builder-style sampling temperature applied to every component.
    pub fn with_temperature(mut self, t: f64) -> MixedWorkloadGen {
        self.components = self
            .components
            .into_iter()
            .map(|c| c.with_temperature(t))
            .collect();
        self
    }

    /// Clamp lengths on every component (see [`WorkloadGen::with_limits`]).
    pub fn with_limits(mut self, max_prompt: usize, max_output: usize) -> MixedWorkloadGen {
        self.components = self
            .components
            .into_iter()
            .map(|c| c.with_limits(max_prompt, max_output))
            .collect();
        self
    }

    /// Component dataset names, in insertion order.
    pub fn component_names(&self) -> Vec<&'static str> {
        self.components.iter().map(|c| c.dataset().name()).collect()
    }

    /// Component `(profile, weight)` pairs, in insertion order — the input
    /// [`crate::sim::regime::DatasetProfile::blend`] takes to build the
    /// simulator regime a mixed-tenant cell runs against.
    pub fn component_profiles(&self) -> Vec<(DatasetProfile, f64)> {
        self.components
            .iter()
            .zip(&self.weights)
            .map(|(c, &w)| (c.dataset().profile.clone(), w))
            .collect()
    }

    /// Synthesize one request from a weight-drawn component (ids are
    /// mixture-global and sequential).
    pub fn next_request(&mut self) -> Request {
        assert!(!self.components.is_empty(), "mix has no components");
        let i = self.rng.categorical(&self.weights);
        let mut req = self.components[i].next_request();
        req.id = self.next_id;
        self.next_id += 1;
        req
    }

    /// A batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

impl RequestSource for MixedWorkloadGen {
    fn next_request(&mut self) -> Request {
        MixedWorkloadGen::next_request(self)
    }
}

/// One synthetic tenant in a [`TenantMix`]: a stable name, a priority
/// class, an optional per-request deadline, and a selection weight.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Stable tenant name (auto-derived as `t{idx}-{class}` when parsed).
    pub name: String,
    /// Priority class stamped onto this tenant's requests.
    pub class: PriorityClass,
    /// Optional deadline stamped onto this tenant's requests.
    pub deadline_ms: Option<u64>,
    /// Positive selection weight for the per-request categorical draw.
    pub weight: f64,
}

/// Weighted mixture of synthetic tenants stamped over a request stream —
/// the `--tenants` grid axis.  A mix does not generate requests itself; it
/// decorates requests drawn from any [`RequestSource`] with tenancy
/// attribution (tenant name, priority class, deadline), so the same
/// workload bytes flow under different tenancy policies.
pub struct TenantMix {
    tenants: Vec<TenantSpec>,
    rng: Rng,
}

impl TenantMix {
    /// Parse a tenant-mix spec: components joined with `+` or `,`, each of
    /// the form `<class>[@<deadline_ms>][=<weight>]` where `<class>` is a
    /// [`PriorityClass`] spelling (`interactive`, `standard`,
    /// `best-effort`, ...).  Weights default to 1; tenant names are
    /// auto-derived as `t{idx}-{class}`.  `"none"` and the empty string
    /// mean *no tenancy* and parse to `None`-of-a-mix via
    /// [`TenantMix::parse_opt`]; here they are rejected like any other
    /// malformed spec.
    pub fn parse(spec: &str, seed: u64) -> Option<TenantMix> {
        let mut tenants = Vec::new();
        for part in spec.split(['+', ',']).filter(|p| !p.trim().is_empty()) {
            let (head, weight) = match part.split_once('=') {
                Some((h, w)) => (h.trim(), w.trim().parse::<f64>().ok()?),
                None => (part.trim(), 1.0),
            };
            if weight <= 0.0 || weight.is_nan() {
                return None;
            }
            let (class_s, deadline_ms) = match head.split_once('@') {
                Some((c, d)) => (c.trim(), Some(d.trim().parse::<u64>().ok()?)),
                None => (head, None),
            };
            let class = PriorityClass::parse(class_s)?;
            tenants.push(TenantSpec {
                name: format!("t{}-{}", tenants.len(), class.name()),
                class,
                deadline_ms,
                weight,
            });
        }
        if tenants.is_empty() {
            None
        } else {
            Some(TenantMix {
                tenants,
                rng: Rng::new(seed ^ 0x7E4A_4E54), // "TENT"-ish
            })
        }
    }

    /// Like [`TenantMix::parse`], but treats `"none"` and the empty string
    /// as the explicit *no tenancy* spelling: `Ok(None)`.  Any other
    /// unparsable spec is `Err`.
    pub fn parse_opt(spec: &str, seed: u64) -> Result<Option<TenantMix>, String> {
        let s = spec.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(None);
        }
        TenantMix::parse(s, seed)
            .map(Some)
            .ok_or_else(|| format!("bad tenant mix spec: {spec:?}"))
    }

    /// The parsed tenant specs, in spec order.
    pub fn tenants(&self) -> &[TenantSpec] {
        &self.tenants
    }

    /// Stamp one request with a weight-drawn tenant's attribution.  The
    /// request's prompt/sampling bytes are untouched — tenancy is a strict
    /// superset decoration, so stamped and unstamped streams decode
    /// identically.
    pub fn stamp(&mut self, req: &mut Request) {
        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        let t = &self.tenants[self.rng.categorical(&weights)];
        req.tenant = t.name.clone();
        req.class = t.class;
        req.deadline_ms = t.deadline_ms;
    }
}

/// Poisson arrival process (for open-loop server experiments).
pub struct PoissonArrivals {
    rng: Rng,
    rate: f64,
    next_at: f64,
}

impl PoissonArrivals {
    /// A Poisson process with `rate_per_s` expected arrivals per second.
    pub fn new(rate_per_s: f64, seed: u64) -> PoissonArrivals {
        let mut rng = Rng::new(seed);
        let first = rng.exponential(rate_per_s);
        PoissonArrivals {
            rng,
            rate: rate_per_s,
            next_at: first,
        }
    }

    /// Number of arrivals in (now - dt, now]; advances internal state.
    pub fn arrivals_until(&mut self, now: f64) -> usize {
        let mut n = 0;
        while self.next_at <= now {
            n += 1;
            self.next_at += self.rng.exponential(self.rate);
        }
        n
    }

    /// Absolute time of the next arrival; advances internal state.  Used by
    /// the eval grid's virtual-time open-loop driver, which needs the
    /// arrival *times* rather than windowed counts.
    pub fn next_arrival(&mut self) -> f64 {
        let t = self.next_at;
        self.next_at += self.rng.exponential(self.rate);
        t
    }
}

/// Bursty (on/off Markov-modulated Poisson) arrival process — the burst
/// overlay the eval grid layers over [`PoissonArrivals`]: exponential-length
/// *gap* phases at `base_rate` alternate with exponential-length *burst*
/// phases at `burst_rate`, reproducing the correlated traffic spikes of
/// real multi-tenant serving that a constant-rate process smooths away.
pub struct BurstyArrivals {
    rng: Rng,
    base_rate: f64,
    burst_rate: f64,
    mean_burst_s: f64,
    mean_gap_s: f64,
    in_burst: bool,
    phase_end: f64,
    next_at: f64,
}

impl BurstyArrivals {
    /// A process starting in a gap phase.  `base_rate`/`burst_rate` are
    /// arrivals per second in each phase; `mean_gap_s`/`mean_burst_s` are
    /// the expected phase lengths.
    pub fn new(
        base_rate: f64,
        burst_rate: f64,
        mean_gap_s: f64,
        mean_burst_s: f64,
        seed: u64,
    ) -> BurstyArrivals {
        assert!(base_rate > 0.0 && burst_rate > 0.0);
        assert!(mean_gap_s > 0.0 && mean_burst_s > 0.0);
        let mut rng = Rng::new(seed ^ 0xB5_7A11);
        let phase_end = rng.exponential(1.0 / mean_gap_s);
        let next_at = rng.exponential(base_rate);
        BurstyArrivals {
            rng,
            base_rate,
            burst_rate,
            mean_burst_s,
            mean_gap_s,
            in_burst: false,
            phase_end,
            next_at,
        }
    }

    /// Whether the process is currently inside a burst phase.
    pub fn in_burst(&self) -> bool {
        self.in_burst
    }

    fn rate(&self) -> f64 {
        if self.in_burst {
            self.burst_rate
        } else {
            self.base_rate
        }
    }

    /// Number of arrivals in (now - dt, now]; advances internal state
    /// (phase flips resample the arrival clock at the boundary — exact for
    /// the memoryless exponential).
    pub fn arrivals_until(&mut self, now: f64) -> usize {
        let mut n = 0;
        loop {
            if self.phase_end <= now && self.phase_end <= self.next_at {
                // the phase flips before the next arrival fires
                let t0 = self.phase_end;
                self.in_burst = !self.in_burst;
                let mean = if self.in_burst {
                    self.mean_burst_s
                } else {
                    self.mean_gap_s
                };
                self.phase_end = t0 + self.rng.exponential(1.0 / mean);
                self.next_at = t0 + self.rng.exponential(self.rate());
                continue;
            }
            if self.next_at <= now {
                n += 1;
                self.next_at += self.rng.exponential(self.rate());
                continue;
            }
            return n;
        }
    }

    /// Absolute time of the next arrival; advances internal state (the
    /// [`BurstyArrivals::arrivals_until`] phase-flip logic, restated for
    /// callers that consume arrival times one by one).
    pub fn next_arrival(&mut self) -> f64 {
        loop {
            if self.phase_end <= self.next_at {
                let t0 = self.phase_end;
                self.in_burst = !self.in_burst;
                let mean = if self.in_burst {
                    self.mean_burst_s
                } else {
                    self.mean_gap_s
                };
                self.phase_end = t0 + self.rng.exponential(1.0 / mean);
                self.next_at = t0 + self.rng.exponential(self.rate());
                continue;
            }
            let t = self.next_at;
            self.next_at += self.rng.exponential(self.rate());
            return t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_datasets_present() {
        let names: Vec<&str> = Dataset::all().iter().map(|d| d.name()).collect();
        for want in [
            "cnndm", "xsum", "gsm8k", "hotpotqa", "nq", "humaneval", "sharegpt",
            "wmt14",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let mk = || {
            let mut g = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
            g.batch(5)
                .iter()
                .map(|r| (r.prompt.clone(), r.params.max_tokens))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn limits_respected() {
        let mut g = WorkloadGen::new(Dataset::by_name("humaneval").unwrap(), 1)
            .with_limits(48, 80);
        for r in g.batch(50) {
            assert!(r.prompt.len() <= 48);
            assert!(r.params.max_tokens <= 80);
        }
    }

    #[test]
    fn ids_are_unique_and_sequential() {
        let mut g = WorkloadGen::new(Dataset::by_name("nq").unwrap(), 2);
        let ids: Vec<u64> = g.batch(10).iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn code_prompts_look_like_code() {
        let mut g = WorkloadGen::new(Dataset::by_name("humaneval").unwrap(), 3);
        let r = g.next_request();
        let text = vocab::decode(&r.prompt);
        assert!(text.contains("def "), "{text}");
    }

    #[test]
    fn temperature_propagates() {
        let mut g =
            WorkloadGen::new(Dataset::by_name("xsum").unwrap(), 4).with_temperature(1.0);
        assert_eq!(g.next_request().params.temperature, 1.0);
    }

    #[test]
    fn poisson_rate_approximately_met() {
        let mut p = PoissonArrivals::new(10.0, 5);
        let n = p.arrivals_until(100.0);
        assert!((800..1200).contains(&n), "n = {n}");
    }

    #[test]
    fn poisson_monotone_consumption() {
        let mut p = PoissonArrivals::new(5.0, 6);
        let a = p.arrivals_until(10.0);
        let b = p.arrivals_until(10.0); // same time again -> nothing new
        assert!(a > 0);
        assert_eq!(b, 0);
    }

    #[test]
    fn tight_limits_are_clamped_not_exceeded() {
        // the low-max_output grid-cell fix: limits below the natural floors
        // (prompt 8 / output 4) must still be honored, down to 1 token
        let mut g = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 7)
            .with_limits(4, 2);
        for r in g.batch(50) {
            assert!((1..=4).contains(&r.prompt.len()), "{}", r.prompt.len());
            assert!((1..=2).contains(&r.params.max_tokens), "{}", r.params.max_tokens);
        }
        // degenerate limit of 0 degrades to 1, never to a panic or a 0-token
        // request the engine could stall on
        let mut g = WorkloadGen::new(Dataset::by_name("nq").unwrap(), 8)
            .with_limits(0, 0);
        let r = g.next_request();
        assert_eq!(r.prompt.len(), 1);
        assert_eq!(r.params.max_tokens, 1);
    }

    #[test]
    fn mixed_generator_draws_all_components_deterministically() {
        let mk = || {
            let mut m = MixedWorkloadGen::parse("sharegpt=2+humaneval=1", 42).unwrap();
            m.batch(60)
                .iter()
                .map(|r| (r.id, r.prompt.clone(), r.params.max_tokens))
                .collect::<Vec<_>>()
        };
        let a = mk();
        assert_eq!(a, mk(), "mixes must be seed-deterministic");
        // ids are mixture-global and sequential
        assert_eq!(
            a.iter().map(|(id, _, _)| *id).collect::<Vec<_>>(),
            (0..60).collect::<Vec<u64>>()
        );
        // both task flavors appear: humaneval prompts are code-shaped
        let texts: Vec<String> =
            a.iter().map(|(_, p, _)| vocab::decode(p)).collect();
        assert!(texts.iter().any(|t| t.contains("def ")), "code component");
        assert!(texts.iter().any(|t| t.contains("User:")), "dialogue component");
    }

    #[test]
    fn mixed_generator_respects_weights_and_limits() {
        let mut m = MixedWorkloadGen::new(5)
            .with_component(Dataset::by_name("sharegpt").unwrap(), 9.0)
            .with_component(Dataset::by_name("humaneval").unwrap(), 1.0)
            .with_limits(32, 16);
        let reqs = m.batch(300);
        let code = reqs
            .iter()
            .filter(|r| vocab::decode(&r.prompt).contains("def "))
            .count();
        // ~10% expected; allow a generous band
        assert!(code < 90, "code fraction too high: {code}/300");
        assert!(code > 2, "code component never drawn: {code}/300");
        for r in &reqs {
            assert!(r.prompt.len() <= 32);
            assert!(r.params.max_tokens <= 16);
        }
        assert_eq!(m.component_names(), vec!["sharegpt", "humaneval"]);
    }

    #[test]
    fn mix_parse_rejects_garbage() {
        assert!(MixedWorkloadGen::parse("bogus=1", 0).is_none());
        assert!(MixedWorkloadGen::parse("cnndm=0", 0).is_none());
        assert!(MixedWorkloadGen::parse("cnndm=-2", 0).is_none());
        assert!(MixedWorkloadGen::parse("cnndm=nan", 0).is_none());
        assert!(MixedWorkloadGen::parse("", 0).is_none());
        assert!(MixedWorkloadGen::parse("cnndm,xsum=3", 0).is_some());
    }

    #[test]
    fn bursty_rate_between_base_and_burst() {
        let mut b = BurstyArrivals::new(2.0, 40.0, 8.0, 2.0, 11);
        let n = b.arrivals_until(2000.0);
        // stationary mean rate = (2*8 + 40*2) / (8+2) = 9.6/s
        let rate = n as f64 / 2000.0;
        assert!(rate > 3.0 && rate < 25.0, "long-run rate {rate}");
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Fano factor (window-count variance / mean) ~ 1 for Poisson, >> 1
        // for a strongly modulated on/off process
        let fano = |counts: &[usize]| -> f64 {
            let n = counts.len() as f64;
            let mean = counts.iter().sum::<usize>() as f64 / n;
            let var = counts
                .iter()
                .map(|&c| (c as f64 - mean).powi(2))
                .sum::<f64>()
                / n;
            var / mean.max(1e-9)
        };
        let mut bursty = BurstyArrivals::new(2.0, 40.0, 8.0, 2.0, 13);
        let bc: Vec<usize> = (1..=2000).map(|t| bursty.arrivals_until(t as f64)).collect();
        let mut flat = PoissonArrivals::new(9.6, 13);
        let fc: Vec<usize> = (1..=2000).map(|t| flat.arrivals_until(t as f64)).collect();
        let fb = fano(&bc);
        let fp = fano(&fc);
        assert!(fb > 2.0 * fp, "bursty fano {fb:.2} vs poisson {fp:.2}");
        assert!(fp < 2.0, "poisson fano {fp:.2}");
    }

    #[test]
    fn next_arrival_times_match_windowed_counts() {
        let mut a = PoissonArrivals::new(4.0, 21);
        let mut b = PoissonArrivals::new(4.0, 21);
        let times: Vec<f64> = (0..50).map(|_| a.next_arrival()).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "nondecreasing");
        assert_eq!(b.arrivals_until(times[49]), 50);
    }

    #[test]
    fn bursty_next_arrival_monotone() {
        let mut b = BurstyArrivals::new(2.0, 40.0, 8.0, 2.0, 23);
        let times: Vec<f64> = (0..200).map(|_| b.next_arrival()).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "nondecreasing");
    }

    #[test]
    fn component_profiles_expose_weights() {
        let m = MixedWorkloadGen::parse("cnndm=3+humaneval", 1).unwrap();
        let parts = m.component_profiles();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].0.name, "cnndm");
        assert_eq!(parts[0].1, 3.0);
        assert_eq!(parts[1].0.name, "humaneval");
        assert_eq!(parts[1].1, 1.0);
    }

    #[test]
    fn tenant_mix_parses_classes_deadlines_and_weights() {
        let m = TenantMix::parse("interactive@400=3+best-effort", 1).unwrap();
        let t = m.tenants();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "t0-interactive");
        assert_eq!(t[0].class, PriorityClass::Interactive);
        assert_eq!(t[0].deadline_ms, Some(400));
        assert_eq!(t[0].weight, 3.0);
        assert_eq!(t[1].name, "t1-best-effort");
        assert_eq!(t[1].class, PriorityClass::BestEffort);
        assert_eq!(t[1].deadline_ms, None);
        assert_eq!(t[1].weight, 1.0);
    }

    #[test]
    fn tenant_mix_parse_rejects_garbage() {
        assert!(TenantMix::parse("bogus", 0).is_none());
        assert!(TenantMix::parse("interactive=0", 0).is_none());
        assert!(TenantMix::parse("interactive=-1", 0).is_none());
        assert!(TenantMix::parse("interactive@abc", 0).is_none());
        assert!(TenantMix::parse("", 0).is_none());
        assert!(TenantMix::parse_opt("none", 0).unwrap().is_none());
        assert!(TenantMix::parse_opt("", 0).unwrap().is_none());
        assert!(TenantMix::parse_opt("garbage", 0).is_err());
        assert!(TenantMix::parse_opt("standard+interactive@250", 0)
            .unwrap()
            .is_some());
    }

    #[test]
    fn tenant_mix_stamps_attribution_without_touching_payload() {
        let mut g = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
        let plain = g.batch(40);
        let mut g2 = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
        let mut mix = TenantMix::parse("interactive@400=1+best-effort=1", 9).unwrap();
        let stamped: Vec<Request> = g2
            .batch(40)
            .into_iter()
            .map(|mut r| {
                mix.stamp(&mut r);
                r
            })
            .collect();
        // payload bytes are identical — tenancy is a pure decoration
        for (a, b) in plain.iter().zip(&stamped) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.params.max_tokens, b.params.max_tokens);
        }
        // both tenants appear, and each carries its spec's class/deadline
        let interactive = stamped
            .iter()
            .filter(|r| r.tenant == "t0-interactive")
            .count();
        let besteffort = stamped
            .iter()
            .filter(|r| r.tenant == "t1-best-effort")
            .count();
        assert_eq!(interactive + besteffort, 40);
        assert!(interactive > 0 && besteffort > 0);
        for r in &stamped {
            if r.tenant == "t0-interactive" {
                assert_eq!(r.class, PriorityClass::Interactive);
                assert_eq!(r.deadline_ms, Some(400));
            } else {
                assert_eq!(r.class, PriorityClass::BestEffort);
                assert_eq!(r.deadline_ms, None);
            }
        }
        // stamping is seed-deterministic
        let mut mix2 = TenantMix::parse("interactive@400=1+best-effort=1", 9).unwrap();
        let mut g3 = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
        let again: Vec<String> = g3
            .batch(40)
            .into_iter()
            .map(|mut r| {
                mix2.stamp(&mut r);
                r.tenant
            })
            .collect();
        let first: Vec<String> = stamped.iter().map(|r| r.tenant.clone()).collect();
        assert_eq!(first, again);
    }

    #[test]
    fn bursty_monotone_consumption() {
        let mut b = BurstyArrivals::new(5.0, 20.0, 2.0, 1.0, 17);
        let a = b.arrivals_until(50.0);
        assert!(a > 0);
        assert_eq!(b.arrivals_until(50.0), 0);
    }
}
