//! Workload generation: the paper's eight evaluation datasets as synthetic
//! request generators (prompt text of the right task flavor + length and
//! output-length distributions from the dataset profile), plus arrival
//! processes for open-loop serving experiments.

use crate::engine::request::{Request, SamplingParams};
use crate::model::vocab;
use crate::sim::regime::DatasetProfile;
use crate::util::rng::Rng;

/// A named dataset workload.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Length/acceptance profile backing this dataset's simulator regime.
    pub profile: DatasetProfile,
}

impl Dataset {
    /// Look up one of the paper's eight datasets by name (e.g. `cnndm`).
    pub fn by_name(name: &str) -> Option<Dataset> {
        DatasetProfile::by_name(name).map(|profile| Dataset { profile })
    }

    /// All eight evaluation datasets.
    pub fn all() -> Vec<Dataset> {
        DatasetProfile::all()
            .into_iter()
            .map(|profile| Dataset { profile })
            .collect()
    }

    /// The dataset's stable name.
    pub fn name(&self) -> &'static str {
        self.profile.name
    }

    /// Task flavor used for prompt text synthesis.
    fn flavor(&self) -> &'static str {
        match self.profile.name {
            "humaneval" => "code",
            "sharegpt" => "dialogue",
            "gsm8k" => "math",
            _ => "prose",
        }
    }
}

/// Deterministic request generator over a dataset.
pub struct WorkloadGen {
    dataset: Dataset,
    rng: Rng,
    next_id: u64,
    temperature: f64,
    /// clamp on generated output length (e.g. context budget of the tiny
    /// PJRT model); usize::MAX = profile-driven only
    pub max_output: usize,
    /// clamp on generated prompt length; usize::MAX = profile-driven only
    pub max_prompt: usize,
}

impl WorkloadGen {
    /// Deterministic generator over `dataset`, seeded for reproducibility.
    pub fn new(dataset: Dataset, seed: u64) -> WorkloadGen {
        WorkloadGen {
            dataset,
            rng: Rng::new(seed),
            next_id: 0,
            temperature: 0.0,
            max_output: usize::MAX,
            max_prompt: usize::MAX,
        }
    }

    /// Builder-style sampling temperature for the generated requests.
    pub fn with_temperature(mut self, t: f64) -> WorkloadGen {
        self.temperature = t;
        self
    }

    /// Constrain lengths (used by the PJRT path whose context is 160).
    pub fn with_limits(mut self, max_prompt: usize, max_output: usize) -> WorkloadGen {
        self.max_prompt = max_prompt;
        self.max_output = max_output;
        self
    }

    /// The dataset this generator draws from.
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// Synthesize one request.
    pub fn next_request(&mut self) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let p = &self.dataset.profile;
        // lengths: lognormal-ish around the profile means
        let plen = ((p.mean_prompt as f64) * (0.6 + 0.8 * self.rng.f64())) as usize;
        let plen = plen.clamp(8, self.max_prompt.max(8));
        let olen = ((p.mean_output as f64) * (0.6 + 0.8 * self.rng.f64())) as usize;
        let olen = olen.clamp(4, self.max_output.max(4));
        let prompt = self.prompt_text(plen);
        Request::new(
            id,
            prompt,
            SamplingParams {
                temperature: self.temperature,
                max_tokens: olen,
                stop_token: None,
            },
        )
    }

    /// A batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    fn prompt_text(&mut self, len: usize) -> Vec<u32> {
        let text = match self.dataset.flavor() {
            "code" => Self::code_prompt(&mut self.rng),
            "dialogue" => Self::dialogue_prompt(&mut self.rng),
            "math" => Self::math_prompt(&mut self.rng),
            _ => Self::prose_prompt(&mut self.rng),
        };
        let mut toks = vocab::encode(&text);
        toks.truncate(len);
        while toks.len() < len {
            toks.push(b' ' as u32);
        }
        toks
    }

    fn code_prompt(rng: &mut Rng) -> String {
        let fns = ["compute", "process", "merge", "scan", "reduce"];
        let vars = ["count", "total", "idx", "value", "acc"];
        format!(
            "def {}({}):\n    {} = 0\n    for {} in range({}):\n        ",
            fns[rng.range(0, fns.len())],
            vars[rng.range(0, vars.len())],
            vars[rng.range(0, vars.len())],
            vars[rng.range(0, vars.len())],
            rng.range(2, 64)
        )
    }

    fn dialogue_prompt(rng: &mut Rng) -> String {
        let topics = [
            "the overall cost",
            "a new method",
            "daily traffic",
            "the main problem",
            "future growth",
        ];
        format!(
            "User: Can you explain {} in simple terms?\nAgent: ",
            topics[rng.range(0, topics.len())]
        )
    }

    fn math_prompt(rng: &mut Rng) -> String {
        format!(
            "Q: A box holds {} items and another holds {} items. Each item \
             costs {}. What is the total cost?\nA: ",
            rng.range(2, 40),
            rng.range(2, 40),
            rng.range(2, 12)
        )
    }

    fn prose_prompt(rng: &mut Rng) -> String {
        let subjects = ["The system", "A model", "The report", "The market"];
        format!(
            "{} shows the results clearly. Summarize: ",
            subjects[rng.range(0, subjects.len())]
        )
    }
}

/// Poisson arrival process (for open-loop server experiments).
pub struct PoissonArrivals {
    rng: Rng,
    rate: f64,
    next_at: f64,
}

impl PoissonArrivals {
    /// A Poisson process with `rate_per_s` expected arrivals per second.
    pub fn new(rate_per_s: f64, seed: u64) -> PoissonArrivals {
        let mut rng = Rng::new(seed);
        let first = rng.exponential(rate_per_s);
        PoissonArrivals {
            rng,
            rate: rate_per_s,
            next_at: first,
        }
    }

    /// Number of arrivals in (now - dt, now]; advances internal state.
    pub fn arrivals_until(&mut self, now: f64) -> usize {
        let mut n = 0;
        while self.next_at <= now {
            n += 1;
            self.next_at += self.rng.exponential(self.rate);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_datasets_present() {
        let names: Vec<&str> = Dataset::all().iter().map(|d| d.name()).collect();
        for want in [
            "cnndm", "xsum", "gsm8k", "hotpotqa", "nq", "humaneval", "sharegpt",
            "wmt14",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let mk = || {
            let mut g = WorkloadGen::new(Dataset::by_name("cnndm").unwrap(), 42);
            g.batch(5)
                .iter()
                .map(|r| (r.prompt.clone(), r.params.max_tokens))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn limits_respected() {
        let mut g = WorkloadGen::new(Dataset::by_name("humaneval").unwrap(), 1)
            .with_limits(48, 80);
        for r in g.batch(50) {
            assert!(r.prompt.len() <= 48);
            assert!(r.params.max_tokens <= 80);
        }
    }

    #[test]
    fn ids_are_unique_and_sequential() {
        let mut g = WorkloadGen::new(Dataset::by_name("nq").unwrap(), 2);
        let ids: Vec<u64> = g.batch(10).iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn code_prompts_look_like_code() {
        let mut g = WorkloadGen::new(Dataset::by_name("humaneval").unwrap(), 3);
        let r = g.next_request();
        let text = vocab::decode(&r.prompt);
        assert!(text.contains("def "), "{text}");
    }

    #[test]
    fn temperature_propagates() {
        let mut g =
            WorkloadGen::new(Dataset::by_name("xsum").unwrap(), 4).with_temperature(1.0);
        assert_eq!(g.next_request().params.temperature, 1.0);
    }

    #[test]
    fn poisson_rate_approximately_met() {
        let mut p = PoissonArrivals::new(10.0, 5);
        let n = p.arrivals_until(100.0);
        assert!((800..1200).contains(&n), "n = {n}");
    }

    #[test]
    fn poisson_monotone_consumption() {
        let mut p = PoissonArrivals::new(5.0, 6);
        let a = p.arrivals_until(10.0);
        let b = p.arrivals_until(10.0); // same time again -> nothing new
        assert!(a > 0);
        assert_eq!(b, 0);
    }
}
