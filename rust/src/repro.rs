//! Reproduction harness: one-call experiment runner shared by the paper
//! bench binaries (`rust/benches/*`) and scriptable from downstream code.

use crate::config::{CapMode, EngineConfig, SlPolicyKind};
use crate::engine::engine::Engine;
use crate::engine::metrics::EngineMetrics;
use crate::model::sim_lm::{SimModel, SimPairKind};
use crate::sim::regime::DatasetProfile;
use crate::workload::{Dataset, WorkloadGen};

/// One experiment's specification.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub dataset: &'static str,
    pub pair: SimPairKind,
    pub policy: SlPolicyKind,
    pub cap: CapMode,
    pub speculative: bool,
    pub batch: usize,
    pub requests: usize,
    pub temperature: f64,
    pub seed: u64,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            dataset: "cnndm",
            pair: SimPairKind::LlamaLike,
            policy: SlPolicyKind::Static(4),
            cap: CapMode::Mean,
            speculative: true,
            batch: 8,
            requests: 128,
            temperature: 0.0,
            seed: 0,
        }
    }
}

/// Run one simulated experiment and return the engine metrics.
pub fn run(spec: &ExperimentSpec) -> EngineMetrics {
    let profile = DatasetProfile::by_name(spec.dataset).expect("dataset");
    let cfg = EngineConfig {
        max_batch: spec.batch,
        max_len: 4096,
        speculative: spec.speculative,
        policy: spec.policy.clone(),
        cap_mode: spec.cap,
        kv_blocks: 65536,
        temperature: spec.temperature,
        seed: spec.seed,
        ..Default::default()
    };
    let model = SimModel::new(spec.pair, profile, spec.seed);
    let mut engine = Engine::new(cfg, Box::new(model));
    let mut gen = WorkloadGen::new(Dataset::by_name(spec.dataset).unwrap(), spec.seed)
        .with_temperature(spec.temperature)
        .with_limits(96, 256);
    for req in gen.batch(spec.requests) {
        engine.submit(req);
    }
    engine.run_to_completion();
    engine.metrics.clone()
}

/// Sweep static SL values and return (k, metrics) — the paper's costly
/// "static-opt" profiling pass (Fig. 6 / Table 3 baseline).
pub fn static_sweep(
    base: &ExperimentSpec,
    ks: &[usize],
) -> Vec<(usize, EngineMetrics)> {
    ks.iter()
        .map(|&k| {
            let mut spec = base.clone();
            spec.policy = SlPolicyKind::Static(k);
            (k, run(&spec))
        })
        .collect()
}

/// The static-opt latency: best mean latency over the sweep.
pub fn static_opt(base: &ExperimentSpec, ks: &[usize]) -> (usize, EngineMetrics) {
    static_sweep(base, ks)
        .into_iter()
        .min_by(|a, b| {
            a.1.mean_latency()
                .partial_cmp(&b.1.mean_latency())
                .unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_returns_metrics() {
        let spec = ExperimentSpec {
            requests: 8,
            ..Default::default()
        };
        let m = run(&spec);
        assert_eq!(m.requests.len(), 8);
        assert!(m.mean_latency() > 0.0);
    }

    #[test]
    fn static_opt_picks_minimum() {
        let spec = ExperimentSpec {
            requests: 8,
            ..Default::default()
        };
        let sweep = static_sweep(&spec, &[2, 6]);
        let (k_opt, m_opt) = static_opt(&spec, &[2, 6]);
        for (k, m) in &sweep {
            if *k == k_opt {
                assert!((m.mean_latency() - m_opt.mean_latency()).abs() < 1e-9);
            } else {
                assert!(m.mean_latency() >= m_opt.mean_latency());
            }
        }
    }
}
