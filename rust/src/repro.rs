//! Reproduction harness: one-call experiment runner shared by the paper
//! bench binaries (`rust/benches/*`), the [`crate::eval`] grid runner, and
//! downstream scripts.  An [`ExperimentSpec`] names one point in the
//! evaluation space; [`run`] executes it closed-loop on a single simulated
//! engine, and the [`build_engine`] / [`build_workload`] halves are exposed
//! so the eval subsystem can route the same cells through a multi-replica
//! [`crate::server::router::EngineRouter`] or an open-loop arrival driver.

use crate::config::{CapMode, EngineConfig, SlPolicyKind};
use crate::engine::engine::Engine;
use crate::engine::metrics::EngineMetrics;
use crate::model::sim_lm::{SimModel, SimPairKind};
use crate::sim::regime::DatasetProfile;
use crate::workload::{Dataset, WorkloadGen};

/// One experiment's specification.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// Dataset name (one of the paper's eight; see
    /// [`DatasetProfile::by_name`]).
    pub dataset: &'static str,
    /// Which draft/target pair the simulator emulates.
    pub pair: SimPairKind,
    /// SL policy under test.
    pub policy: SlPolicyKind,
    /// Batch-wide SL-cap mode (paper §3.3).
    pub cap: CapMode,
    /// Speculative decoding on (false = autoregressive baseline).
    pub speculative: bool,
    /// Scheduler batch size.
    pub batch: usize,
    /// Requests submitted (closed loop).
    pub requests: usize,
    /// Sampling temperature for workload and engine.
    pub temperature: f64,
    /// Seed for model, engine sampling, and workload streams.
    pub seed: u64,
    /// Extra acceptance scaling on top of the pair's
    /// ([`DatasetProfile::with_divergence`]); `1.0` = the pair's native
    /// regime, `< 1` = low-acceptance stress (paper §4.4).
    pub divergence: f64,
    /// Prompt-length clamp applied to the workload generator.
    pub max_prompt: usize,
    /// Output-length clamp applied to the workload generator.
    pub max_output: usize,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            dataset: "cnndm",
            pair: SimPairKind::LlamaLike,
            policy: SlPolicyKind::Static(4),
            cap: CapMode::Mean,
            speculative: true,
            batch: 8,
            requests: 128,
            temperature: 0.0,
            seed: 0,
            divergence: 1.0,
            max_prompt: 96,
            max_output: 256,
        }
    }
}

impl ExperimentSpec {
    /// The dataset profile this spec runs against, with the divergence
    /// scaling applied.
    pub fn profile(&self) -> DatasetProfile {
        DatasetProfile::by_name(self.dataset)
            .expect("dataset")
            .with_divergence(self.divergence)
    }
}

/// Build the simulated engine a spec describes (no requests submitted).
pub fn build_engine(spec: &ExperimentSpec) -> Engine {
    build_engine_with_profile(spec, spec.profile())
}

/// Like [`build_engine`] but over an explicit profile — the eval grid uses
/// this for blended multi-tenant regimes that have no dataset name.
pub fn build_engine_with_profile(spec: &ExperimentSpec, profile: DatasetProfile) -> Engine {
    let cfg = EngineConfig {
        max_batch: spec.batch,
        max_len: 4096,
        speculative: spec.speculative,
        policy: spec.policy.clone(),
        cap_mode: spec.cap,
        kv_blocks: 65536,
        temperature: spec.temperature,
        seed: spec.seed,
        ..Default::default()
    };
    let model = SimModel::new(spec.pair, profile, spec.seed);
    Engine::new(cfg, Box::new(model))
}

/// Build the workload generator a spec describes.
pub fn build_workload(spec: &ExperimentSpec) -> WorkloadGen {
    WorkloadGen::new(Dataset::by_name(spec.dataset).expect("dataset"), spec.seed)
        .with_temperature(spec.temperature)
        .with_limits(spec.max_prompt, spec.max_output)
}

/// Run one simulated experiment and return the engine metrics.
pub fn run(spec: &ExperimentSpec) -> EngineMetrics {
    let mut engine = build_engine(spec);
    let mut gen = build_workload(spec);
    for req in gen.batch(spec.requests) {
        engine.submit(req);
    }
    engine.run_to_completion();
    engine.metrics.clone()
}

/// Sweep static SL values and return (k, metrics) — the paper's costly
/// "static-opt" profiling pass (Fig. 6 / Table 3 baseline).
pub fn static_sweep(
    base: &ExperimentSpec,
    ks: &[usize],
) -> Vec<(usize, EngineMetrics)> {
    ks.iter()
        .map(|&k| {
            let mut spec = base.clone();
            spec.policy = SlPolicyKind::Static(k);
            (k, run(&spec))
        })
        .collect()
}

/// The static-opt latency: best mean latency over the sweep.
pub fn static_opt(base: &ExperimentSpec, ks: &[usize]) -> (usize, EngineMetrics) {
    static_sweep(base, ks)
        .into_iter()
        .min_by(|a, b| {
            a.1.mean_latency()
                .partial_cmp(&b.1.mean_latency())
                .unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_returns_metrics() {
        let spec = ExperimentSpec {
            requests: 8,
            ..Default::default()
        };
        let m = run(&spec);
        assert_eq!(m.requests.len(), 8);
        assert!(m.mean_latency() > 0.0);
    }

    #[test]
    fn divergence_stress_lowers_acceptance() {
        let base = ExperimentSpec {
            requests: 16,
            ..Default::default()
        };
        let stressed = ExperimentSpec {
            divergence: 0.5,
            requests: 16,
            ..Default::default()
        };
        let a = run(&base).acceptance_rate();
        let b = run(&stressed).acceptance_rate();
        assert!(b < a, "stressed {b} !< native {a}");
    }

    #[test]
    fn workload_limits_honored() {
        let spec = ExperimentSpec {
            max_prompt: 12,
            max_output: 6,
            requests: 4,
            ..Default::default()
        };
        let mut gen = build_workload(&spec);
        for r in gen.batch(10) {
            assert!(r.prompt.len() <= 12);
            assert!(r.params.max_tokens <= 6);
        }
    }

    #[test]
    fn static_opt_picks_minimum() {
        let spec = ExperimentSpec {
            requests: 8,
            ..Default::default()
        };
        let sweep = static_sweep(&spec, &[2, 6]);
        let (k_opt, m_opt) = static_opt(&spec, &[2, 6]);
        for (k, m) in &sweep {
            if *k == k_opt {
                assert!((m.mean_latency() - m_opt.mean_latency()).abs() < 1e-9);
            } else {
                assert!(m.mean_latency() >= m_opt.mean_latency());
            }
        }
    }
}
