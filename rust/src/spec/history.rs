//! Per-sequence signal history — the data structure behind the paper's
//! Fig. 5: after every verification step the per-token KLD values are
//! aggregated into short-term (N=10) and long-term (N=30) windows, from
//! which the WVIR (Eq. 4) is computed with exponential-decay weights
//! (Eq. 5–7).  Also tracks acceptance statistics for the calibration phase
//! (Eq. 1) and for the AdaEDL baseline's historical acceptance rate.

use crate::util::ring::Ring;
use crate::util::stats::{decay_weights, weighted_variance};

/// Configuration for the history windows.
#[derive(Clone, Copy, Debug)]
pub struct HistoryConfig {
    /// Short window length N_short (paper: 10 steps).
    pub short_window: usize,
    /// Long window length N_long (paper: 30 steps).
    pub long_window: usize,
    /// Exponential decay δ of the window weights (paper: 0.85).
    pub decay: f64,
}

impl Default for HistoryConfig {
    fn default() -> Self {
        // paper: N_short = 10, N_long = 30, δ = 0.85
        HistoryConfig {
            short_window: 10,
            long_window: 30,
            decay: 0.85,
        }
    }
}

/// Rolling signal state for one sequence.
#[derive(Clone, Debug)]
pub struct SeqSignals {
    cfg: HistoryConfig,
    /// per-step mean KLD, newest first via Ring (capacity = long window)
    kld_steps: Ring,
    /// mean KLD of the most recent verified step (μ_KLD,last, Eq. 3)
    pub last_step_mean_kld: f64,
    /// per-step draft entropy mean of the most recent step
    pub last_step_mean_entropy: f64,
    /// number of verification steps observed
    pub steps: usize,
    /// total drafted tokens (block-efficiency bookkeeping)
    pub drafted_total: u64,
    /// total accepted tokens
    pub accepted_total: u64,
    /// EWMA of per-step acceptance rate (AdaEDL's historical signal)
    pub accept_ewma: f64,
    // ---- calibration phase statistics (paper Eq. 1) -------------------------
    /// max tokens accepted in any single calibration step (SL_{A,max})
    pub calib_max_accepted: usize,
    /// running sum of per-token KLD during calibration (μ_KLD,pre)
    pub calib_kld_sum: f64,
    /// number of calibration tokens behind [`SeqSignals::calib_kld_sum`]
    pub calib_kld_count: u64,
    /// max single KLD seen during calibration (KLD_{pre,max})
    pub calib_kld_max: f64,
    /// SL_max frozen after the calibration phase completes
    pub calibrated_sl_max: Option<usize>,
}

impl SeqSignals {
    /// Fresh signal state with the given window configuration.
    pub fn new(cfg: HistoryConfig) -> SeqSignals {
        SeqSignals {
            cfg,
            kld_steps: Ring::new(cfg.long_window.max(cfg.short_window)),
            last_step_mean_kld: 0.0,
            last_step_mean_entropy: 0.0,
            steps: 0,
            drafted_total: 0,
            accepted_total: 0,
            accept_ewma: 1.0,
            calib_max_accepted: 0,
            calib_kld_sum: 0.0,
            calib_kld_count: 0,
            calib_kld_max: 0.0,
            calibrated_sl_max: None,
        }
    }

    /// Record one verification step's observations.
    ///
    /// `klds`/`entropies` hold the per-token signals for the tokens that
    /// were actually verified this step (length = drafted k).
    pub fn record_step(
        &mut self,
        klds: &[f32],
        entropies: &[f32],
        drafted: usize,
        accepted: usize,
    ) {
        self.steps += 1;
        self.drafted_total += drafted as u64;
        self.accepted_total += accepted as u64;
        let rate = if drafted > 0 {
            accepted as f64 / drafted as f64
        } else {
            1.0
        };
        self.accept_ewma = 0.8 * self.accept_ewma + 0.2 * rate;
        if !klds.is_empty() {
            let mean_kld =
                klds.iter().map(|&x| x as f64).sum::<f64>() / klds.len() as f64;
            self.last_step_mean_kld = mean_kld;
            self.kld_steps.push(mean_kld);
        }
        if !entropies.is_empty() {
            self.last_step_mean_entropy = entropies
                .iter()
                .map(|&x| x as f64)
                .sum::<f64>()
                / entropies.len() as f64;
        }
    }

    /// Record calibration-phase per-token KLDs + acceptance.
    pub fn record_calibration(&mut self, klds: &[f32], accepted: usize) {
        self.calib_max_accepted = self.calib_max_accepted.max(accepted);
        for &k in klds {
            let k = k as f64;
            self.calib_kld_sum += k;
            self.calib_kld_count += 1;
            self.calib_kld_max = self.calib_kld_max.max(k);
        }
    }

    /// μ_KLD,pre — mean KLD over all calibration tokens.
    pub fn calib_mean_kld(&self) -> f64 {
        if self.calib_kld_count == 0 {
            0.0
        } else {
            self.calib_kld_sum / self.calib_kld_count as f64
        }
    }

    /// Weighted variance of the most recent `n` per-step KLD means (Eq. 7,
    /// values most-recent-first with decay weights from Eq. 5).
    pub fn weighted_var(&self, n: usize) -> f64 {
        let vals = self.kld_steps.latest(n);
        if vals.len() < 2 {
            return 0.0;
        }
        let w = decay_weights(vals.len(), self.cfg.decay);
        weighted_variance(&vals, &w)
    }

    /// WVIR = Var_w(short) / Var_w(long) (Eq. 4).  Returns 1.0 while the
    /// long window is still too empty to be meaningful, and caps the ratio
    /// to avoid FP blowups from a near-zero denominator.
    pub fn wvir(&self) -> f64 {
        let long = self.weighted_var(self.cfg.long_window);
        let short = self.weighted_var(self.cfg.short_window);
        if self.kld_steps.len() < self.cfg.short_window.min(4) || long < 1e-12 {
            return 1.0;
        }
        (short / long).min(1e6)
    }

    /// Overall acceptance rate so far.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_total == 0 {
            1.0
        } else {
            self.accepted_total as f64 / self.drafted_total as f64
        }
    }

    /// Number of per-step KLD means currently retained.
    pub fn history_len(&self) -> usize {
        self.kld_steps.len()
    }
}

impl Default for SeqSignals {
    fn default() -> Self {
        SeqSignals::new(HistoryConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_updates_means() {
        let mut s = SeqSignals::default();
        s.record_step(&[1.0, 3.0], &[0.5, 1.5], 2, 1);
        assert!((s.last_step_mean_kld - 2.0).abs() < 1e-9);
        assert!((s.last_step_mean_entropy - 1.0).abs() < 1e-9);
        assert_eq!(s.steps, 1);
        assert_eq!(s.drafted_total, 2);
        assert_eq!(s.accepted_total, 1);
    }

    #[test]
    fn wvir_is_one_with_sparse_history() {
        let mut s = SeqSignals::default();
        s.record_step(&[1.0], &[0.1], 1, 1);
        assert_eq!(s.wvir(), 1.0);
    }

    #[test]
    fn wvir_detects_recent_instability() {
        let mut s = SeqSignals::default();
        // long stable history...
        for _ in 0..30 {
            s.record_step(&[1.0], &[0.1], 4, 4);
        }
        let stable = s.wvir();
        // ...followed by a volatile burst
        for v in [0.2f32, 3.0, 0.5, 4.0, 0.1, 5.0] {
            s.record_step(&[v], &[0.1], 4, 1);
        }
        let volatile = s.wvir();
        assert!(
            volatile > stable,
            "wvir stable={stable:.4} volatile={volatile:.4}"
        );
        assert!(volatile > 1.0, "short-term var should exceed long-term");
    }

    #[test]
    fn wvir_near_one_for_stationary_signal() {
        let mut s = SeqSignals::default();
        // alternating but stationary signal
        for i in 0..60 {
            let v = if i % 2 == 0 { 1.0 } else { 2.0 };
            s.record_step(&[v], &[0.1], 4, 2);
        }
        let w = s.wvir();
        assert!(w > 0.3 && w < 3.0, "wvir {w}");
    }

    #[test]
    fn calibration_statistics() {
        let mut s = SeqSignals::default();
        s.record_calibration(&[0.5, 1.5], 3);
        s.record_calibration(&[2.0], 5);
        assert_eq!(s.calib_max_accepted, 5);
        assert!((s.calib_mean_kld() - (0.5 + 1.5 + 2.0) / 3.0).abs() < 1e-9);
        assert!((s.calib_kld_max - 2.0).abs() < 1e-12);
    }

    #[test]
    fn acceptance_rate_tracks_totals() {
        let mut s = SeqSignals::default();
        s.record_step(&[1.0; 4], &[0.0; 4], 4, 2);
        s.record_step(&[1.0; 4], &[0.0; 4], 4, 4);
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn ewma_moves_toward_recent_rate() {
        let mut s = SeqSignals::default();
        for _ in 0..20 {
            s.record_step(&[1.0], &[0.0], 4, 0);
        }
        assert!(s.accept_ewma < 0.1, "ewma {}", s.accept_ewma);
    }

    #[test]
    fn empty_step_keeps_last_kld() {
        let mut s = SeqSignals::default();
        s.record_step(&[2.0], &[1.0], 1, 1);
        s.record_step(&[], &[], 0, 0);
        assert!((s.last_step_mean_kld - 2.0).abs() < 1e-12);
    }
}
