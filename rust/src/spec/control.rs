//! Goodput-driven closed-loop speculation control (fleet level).
//!
//! DSDE adapts SL per *sequence* from post-hoc KLD stability; nothing in
//! the core engine adapts the *fleet* to load.  This module closes that
//! loop the way TurboSpec/SpecServe frame it (PAPERS.md): a periodic
//! controller samples per-replica **goodput** — accepted tokens per busy
//! second, net of draft + verification cost — together with batch
//! occupancy and queue depth, and tunes three actuators:
//!
//! * the **global SL cap**: throttle toward SL=1 under saturation, where
//!   deep speculation burns verification compute exactly when the
//!   straggler effect (paper §3.3) hurts most;
//! * per-replica **speculation aggressiveness**: a multiplier in `(0, 1]`
//!   that [`crate::spec::cap::apply_control`] folds into every granted SL;
//! * **batch admission**: the fraction of `max_batch` the scheduler may
//!   fill, stepped down only after the cap has already hit its floor.
//!
//! The decision path is a **pure function of the sampled metric stream**:
//! no wall-clock reads, no RNG.  That makes the controller testable
//! against a plain-code oracle (`tests/control_property.rs`) and
//! bit-reproducible inside the virtual-clock eval runner.  Two mechanisms
//! keep it from oscillating: *hysteresis* (a direction must persist for
//! `hysteresis` consecutive ticks before one actuation step fires) and a
//! relative goodput *deadband* (dips smaller than `deadband` against the
//! reference goodput are ignored).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Batch-admission fractions the controller steps through, mildest first.
/// Admission throttling is the *last* lever down (after the SL cap floors
/// at 1) and the *first* lever released on recovery.
pub const ADMIT_LEVELS: &[f64] = &[1.0, 0.75, 0.5];

/// Static tuning for the goodput controller (no runtime mutation — the
/// controller state machine owns all mutable state).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlConfig {
    /// Upper bound for the global SL cap (the release target); normally
    /// the engines' `spec_k`.
    pub cap_max: usize,
    /// Relative goodput deadband: dips smaller than this fraction of the
    /// reference goodput are treated as noise, not saturation.
    pub deadband: f64,
    /// Consecutive same-direction ticks required before one actuation
    /// step fires (anti-oscillation).
    pub hysteresis: u32,
    /// Mean batch occupancy at or below which the fleet counts as
    /// underloaded (speculate hard, release throttles).
    pub low_occupancy: f64,
    /// Mean batch occupancy at or above which the fleet counts as
    /// saturated (throttle speculation).
    pub high_occupancy: f64,
    /// Aggressiveness floor applied at full saturation.
    pub min_aggressiveness: f64,
    /// Control-loop period in milliseconds (consumed by the *sampling*
    /// layer — the decision path never reads a clock).
    pub interval_ms: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            cap_max: 12,
            deadband: 0.05,
            hysteresis: 2,
            low_occupancy: 0.5,
            high_occupancy: 0.85,
            min_aggressiveness: 0.25,
            interval_ms: 20,
        }
    }
}

impl ControlConfig {
    /// Check invariants the controller's guarantees depend on.
    pub fn validate(&self) -> Result<(), String> {
        if self.cap_max < 1 {
            return Err("cap_max must be >= 1".into());
        }
        if !(0.0..1.0).contains(&self.deadband) {
            return Err("deadband must be in [0, 1)".into());
        }
        if self.hysteresis < 1 {
            return Err("hysteresis must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.low_occupancy)
            || !(0.0..=1.0).contains(&self.high_occupancy)
            || self.low_occupancy >= self.high_occupancy
        {
            return Err("need 0 <= low_occupancy < high_occupancy <= 1".into());
        }
        if self.min_aggressiveness <= 0.0 || self.min_aggressiveness > 1.0 {
            return Err("min_aggressiveness must be in (0, 1]".into());
        }
        if self.interval_ms == 0 {
            return Err("interval_ms must be >= 1".into());
        }
        Ok(())
    }
}

/// One replica's contribution to a control tick, sampled by the serving
/// layer (or synthesized by the eval runner / property tests).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplicaSample {
    /// Accepted tokens per busy second over the sampling window (net
    /// speculation yield — rejected drafts cost verify time but add no
    /// tokens, so they depress this number by construction).
    pub goodput: f64,
    /// Running batch size over `max_batch`, in `[0, 1]`.
    pub occupancy: f64,
    /// Requests waiting in the replica's admission queue.
    pub queue: usize,
    /// Whether the gauges are stale (replica failed, wedged, or not yet
    /// heartbeating).  Stale samples are excluded from fleet aggregates
    /// and actuate nothing on their replica.
    pub stale: bool,
}

/// The controller's output for one tick: the actuator settings every
/// consumer (scheduler admission, cap plumbing, metrics export) reads.
#[derive(Clone, Debug, PartialEq)]
pub struct ControlDecision {
    /// Global SL cap, always within `[1, cap_max]`.
    pub sl_cap: usize,
    /// Admission fraction of `max_batch`, one of [`ADMIT_LEVELS`].
    pub admit_frac: f64,
    /// Per-replica speculation aggressiveness, parallel to the tick's
    /// sample slice; stale replicas get the neutral `1.0`.
    pub aggressiveness: Vec<f64>,
}

/// The deterministic feedback state machine.  Feed it one
/// [`ReplicaSample`] slice per tick (seeded tick order); it returns the
/// actuator settings.  All state transitions are pure functions of the
/// sample stream — see the module docs for the reproducibility contract.
#[derive(Clone, Debug)]
pub struct Controller {
    cfg: ControlConfig,
    cap: usize,
    admit_level: usize,
    pressure: i32,
    ref_goodput: f64,
    adjustments: u64,
    ticks: u64,
}

impl Controller {
    /// Construct with the cap released to `cap_max` and admission open.
    pub fn new(cfg: ControlConfig) -> Controller {
        cfg.validate().expect("invalid control config");
        Controller {
            cap: cfg.cap_max,
            cfg,
            admit_level: 0,
            pressure: 0,
            ref_goodput: 0.0,
            adjustments: 0,
            ticks: 0,
        }
    }

    /// Current global SL cap.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Current admission fraction.
    pub fn admit_frac(&self) -> f64 {
        ADMIT_LEVELS[self.admit_level]
    }

    /// Actuation steps taken since construction (the `/v1/metrics`
    /// `control_adjustments` counter).
    pub fn adjustments(&self) -> u64 {
        self.adjustments
    }

    /// Ticks processed since construction.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Reference goodput the deadband compares against (an EMA of the
    /// mean live goodput, so sustained dips register for several ticks).
    pub fn ref_goodput(&self) -> f64 {
        self.ref_goodput
    }

    /// Which way the fleet is pushing this tick: `-1` throttle, `+1`
    /// release, `0` hold.  Saturation (occupancy) dominates; the goodput
    /// deadband only breaks the mid-band tie.
    fn direction(&self, live: &[ReplicaSample]) -> i32 {
        if live.is_empty() {
            // every gauge is stale: hold, never flail on no information
            return 0;
        }
        let n = live.len() as f64;
        let occ = live.iter().map(|s| s.occupancy).sum::<f64>() / n;
        if occ >= self.cfg.high_occupancy {
            return -1;
        }
        let queued: usize = live.iter().map(|s| s.queue).sum();
        if occ <= self.cfg.low_occupancy && queued <= live.len() {
            return 1;
        }
        let goodput = live.iter().map(|s| s.goodput).sum::<f64>() / n;
        if self.ref_goodput > 0.0
            && goodput < self.ref_goodput * (1.0 - self.cfg.deadband)
        {
            -1
        } else {
            0
        }
    }

    /// Tighten one step: cap first (toward 1), then admission.  Returns
    /// whether anything changed.
    fn step_down(&mut self) -> bool {
        if self.cap > 1 {
            self.cap -= 1;
            true
        } else if self.admit_level + 1 < ADMIT_LEVELS.len() {
            self.admit_level += 1;
            true
        } else {
            false
        }
    }

    /// Release one step: admission first, then cap (toward `cap_max`).
    /// Returns whether anything changed.
    fn step_up(&mut self) -> bool {
        if self.admit_level > 0 {
            self.admit_level -= 1;
            true
        } else if self.cap < self.cfg.cap_max {
            self.cap += 1;
            true
        } else {
            false
        }
    }

    /// Speculation-aggressiveness multiplier for one replica: neutral at
    /// or below `low_occupancy`, the configured floor at or above
    /// `high_occupancy`, linear in between.  Stale replicas get neutral
    /// (their engine thread is gone or wedged; actuating it is
    /// meaningless and would make decisions depend on failure timing).
    pub fn aggressiveness_for(&self, s: &ReplicaSample) -> f64 {
        if s.stale || s.occupancy <= self.cfg.low_occupancy {
            return 1.0;
        }
        if s.occupancy >= self.cfg.high_occupancy {
            return self.cfg.min_aggressiveness;
        }
        let t = (s.occupancy - self.cfg.low_occupancy)
            / (self.cfg.high_occupancy - self.cfg.low_occupancy);
        1.0 + t * (self.cfg.min_aggressiveness - 1.0)
    }

    /// One control tick: accumulate directional pressure, actuate at most
    /// one step once pressure crosses the hysteresis threshold, refresh
    /// the reference goodput, and emit the actuator settings.
    ///
    /// Guarantees (enforced by `tests/control_property.rs`):
    /// * `sl_cap` stays within `[1, cap_max]`;
    /// * a frozen sample stream reaches a fixed point (decisions stop
    ///   changing) within `hysteresis * (cap_max + ADMIT_LEVELS.len())`
    ///   ticks;
    /// * a ramp that stays saturated produces a nonincreasing cap
    ///   trajectory; one that stays idle produces a nondecreasing one.
    pub fn tick(&mut self, samples: &[ReplicaSample]) -> ControlDecision {
        self.ticks += 1;
        let live: Vec<ReplicaSample> =
            samples.iter().copied().filter(|s| !s.stale).collect();
        let dir = self.direction(&live);
        let same_sign =
            (dir < 0 && self.pressure < 0) || (dir > 0 && self.pressure > 0);
        if same_sign {
            self.pressure += dir;
        } else {
            self.pressure = dir;
        }
        if self.pressure.unsigned_abs() >= self.cfg.hysteresis {
            let changed = if self.pressure < 0 {
                self.step_down()
            } else {
                self.step_up()
            };
            if changed {
                self.adjustments += 1;
            }
            self.pressure = 0;
        }
        if !live.is_empty() {
            let mean =
                live.iter().map(|s| s.goodput).sum::<f64>() / live.len() as f64;
            // EMA, not instant tracking: an instant reference would chase a
            // sustained dip down in one tick and the deadband could never
            // accumulate hysteresis pressure
            self.ref_goodput = if self.ref_goodput > 0.0 {
                0.5 * (self.ref_goodput + mean)
            } else {
                mean
            };
        }
        ControlDecision {
            sl_cap: self.cap,
            admit_frac: self.admit_frac(),
            aggressiveness: samples.iter().map(|s| self.aggressiveness_for(s)).collect(),
        }
    }
}

/// Lock-free mailbox the control loop writes and an engine's `plan` stage
/// reads once per step.  Fixed-point milli encoding keeps the cell to
/// three relaxed atomics; the neutral state (uncapped, admission open,
/// aggressiveness 1.0) is bit-exact with no controller at all.
#[derive(Debug)]
pub struct ControlCell {
    sl_cap: AtomicUsize,
    admit_milli: AtomicUsize,
    aggr_milli: AtomicUsize,
}

impl ControlCell {
    /// A cell in the neutral (no-op) state.
    pub fn new() -> ControlCell {
        ControlCell {
            sl_cap: AtomicUsize::new(usize::MAX),
            admit_milli: AtomicUsize::new(1000),
            aggr_milli: AtomicUsize::new(1000),
        }
    }

    /// Publish one replica's actuator settings.
    pub fn store(&self, sl_cap: usize, admit_frac: f64, aggressiveness: f64) {
        self.sl_cap.store(sl_cap, Ordering::Relaxed);
        self.admit_milli
            .store((admit_frac * 1000.0).round() as usize, Ordering::Relaxed);
        self.aggr_milli
            .store((aggressiveness * 1000.0).round() as usize, Ordering::Relaxed);
    }

    /// Read a consistent-enough view for one plan pass.  (The three loads
    /// are independently relaxed; a torn read across a control tick only
    /// mixes two adjacent one-step decisions, which the hysteresis design
    /// already tolerates.)
    pub fn view(&self) -> ControlView {
        ControlView {
            sl_cap: self.sl_cap.load(Ordering::Relaxed),
            admit_frac: self.admit_milli.load(Ordering::Relaxed) as f64 / 1000.0,
            aggressiveness: self.aggr_milli.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }
}

/// One plan pass's snapshot of the control actuators (see
/// [`ControlCell::view`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlView {
    /// Global SL cap (`usize::MAX` = uncapped).
    pub sl_cap: usize,
    /// Admission fraction of `max_batch` in `(0, 1]`.
    pub admit_frac: f64,
    /// Speculation-aggressiveness multiplier in `(0, 1]`.
    pub aggressiveness: f64,
}

impl Default for ControlView {
    fn default() -> Self {
        ControlView {
            sl_cap: usize::MAX,
            admit_frac: 1.0,
            aggressiveness: 1.0,
        }
    }
}

/// Observability mailbox the control loop publishes for `/v1/metrics`
/// (`sl_cap_current`, `control_adjustments`, `goodput_est`).
#[derive(Debug, Default)]
pub struct ControlExport {
    sl_cap: AtomicUsize,
    adjustments: AtomicU64,
    goodput_milli: AtomicU64,
}

impl ControlExport {
    /// Publish the post-tick controller state.
    pub fn publish(&self, sl_cap: usize, adjustments: u64, goodput: f64) {
        self.sl_cap.store(sl_cap, Ordering::Relaxed);
        self.adjustments.store(adjustments, Ordering::Relaxed);
        self.goodput_milli
            .store((goodput.max(0.0) * 1000.0).round() as u64, Ordering::Relaxed);
    }

    /// Last published global SL cap.
    pub fn sl_cap(&self) -> usize {
        self.sl_cap.load(Ordering::Relaxed)
    }

    /// Total actuation steps taken.
    pub fn adjustments(&self) -> u64 {
        self.adjustments.load(Ordering::Relaxed)
    }

    /// Last published fleet goodput estimate (accepted tokens / busy s).
    pub fn goodput(&self) -> f64 {
        self.goodput_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};

    fn sat(n: usize) -> Vec<ReplicaSample> {
        vec![
            ReplicaSample {
                goodput: 40.0,
                occupancy: 1.0,
                queue: 8,
                stale: false,
            };
            n
        ]
    }

    fn idle(n: usize) -> Vec<ReplicaSample> {
        vec![
            ReplicaSample {
                goodput: 40.0,
                occupancy: 0.1,
                queue: 0,
                stale: false,
            };
            n
        ]
    }

    #[test]
    fn saturation_walks_cap_down_then_admission() {
        let cfg = ControlConfig {
            cap_max: 4,
            ..Default::default()
        };
        let mut c = Controller::new(cfg);
        let mut caps = Vec::new();
        for _ in 0..40 {
            caps.push(c.tick(&sat(2)).sl_cap);
        }
        assert!(caps.windows(2).all(|w| w[1] <= w[0]), "nonincreasing: {caps:?}");
        assert_eq!(c.cap(), 1, "cap floors at 1 under sustained saturation");
        assert_eq!(
            c.admit_frac(),
            *ADMIT_LEVELS.last().unwrap(),
            "admission throttles only after the cap floors"
        );
    }

    #[test]
    fn idle_fleet_releases_back_to_cap_max() {
        let cfg = ControlConfig {
            cap_max: 6,
            ..Default::default()
        };
        let mut c = Controller::new(cfg);
        for _ in 0..40 {
            c.tick(&sat(2));
        }
        assert_eq!(c.cap(), 1);
        let mut caps = Vec::new();
        for _ in 0..40 {
            caps.push(c.tick(&idle(2)).sl_cap);
        }
        assert!(caps.windows(2).all(|w| w[1] >= w[0]), "nondecreasing: {caps:?}");
        assert_eq!(c.cap(), 6, "released to cap_max");
        assert_eq!(c.admit_frac(), 1.0, "admission released first");
    }

    #[test]
    fn hysteresis_blocks_single_tick_blips() {
        let mut c = Controller::new(ControlConfig {
            hysteresis: 3,
            ..Default::default()
        });
        // alternate saturated / mid-band: pressure never persists 3 ticks
        let mid = vec![ReplicaSample {
            goodput: 40.0,
            occupancy: 0.7,
            queue: 2,
            stale: false,
        }];
        for _ in 0..20 {
            c.tick(&sat(1));
            c.tick(&mid);
        }
        assert_eq!(c.adjustments(), 0, "no actuation without persistence");
        assert_eq!(c.cap(), c.cfg.cap_max);
    }

    #[test]
    fn goodput_dip_within_deadband_is_ignored() {
        let mut c = Controller::new(ControlConfig::default());
        let mk = |g: f64| {
            vec![ReplicaSample {
                goodput: g,
                occupancy: 0.7,
                queue: 2,
                stale: false,
            }]
        };
        c.tick(&mk(100.0)); // establishes ref_goodput = 100
        for _ in 0..10 {
            c.tick(&mk(97.0)); // -3% < 5% deadband
        }
        assert_eq!(c.adjustments(), 0);
        for _ in 0..10 {
            c.tick(&mk(80.0)); // first dip is -20%; ref then tracks 80
        }
        assert!(c.adjustments() >= 1, "a real dip must actuate");
    }

    #[test]
    fn all_stale_stream_holds_everything() {
        let mut c = Controller::new(ControlConfig::default());
        let stale = vec![
            ReplicaSample {
                stale: true,
                ..Default::default()
            };
            3
        ];
        let before = (c.cap(), c.admit_frac());
        let d = c.tick(&stale);
        for _ in 0..20 {
            c.tick(&stale);
        }
        assert_eq!((c.cap(), c.admit_frac()), before);
        assert_eq!(c.adjustments(), 0);
        assert_eq!(d.aggressiveness, vec![1.0; 3], "stale replicas stay neutral");
    }

    #[test]
    fn aggressiveness_interpolates_between_bands() {
        let c = Controller::new(ControlConfig::default());
        let at = |occ: f64| {
            c.aggressiveness_for(&ReplicaSample {
                goodput: 1.0,
                occupancy: occ,
                queue: 0,
                stale: false,
            })
        };
        assert_eq!(at(0.2), 1.0);
        assert_eq!(at(0.95), c.cfg.min_aggressiveness);
        let mid = at(0.675); // halfway between 0.5 and 0.85
        assert!((mid - (1.0 + c.cfg.min_aggressiveness) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn frozen_stream_reaches_fixed_point() {
        let cfg = ControlConfig::default();
        let bound =
            cfg.hysteresis as usize * (cfg.cap_max + ADMIT_LEVELS.len()) + 1;
        let mut c = Controller::new(cfg);
        let frozen = sat(4);
        for _ in 0..bound {
            c.tick(&frozen);
        }
        let settled = c.tick(&frozen);
        for _ in 0..10 {
            assert_eq!(c.tick(&frozen), settled, "post-fixed-point drift");
        }
    }

    #[test]
    fn cell_roundtrips_and_defaults_neutral() {
        let cell = ControlCell::new();
        assert_eq!(cell.view(), ControlView::default());
        cell.store(3, 0.75, 0.625);
        let v = cell.view();
        assert_eq!(v.sl_cap, 3);
        assert_eq!(v.admit_frac, 0.75);
        assert_eq!(v.aggressiveness, 0.625);
    }

    #[test]
    fn export_roundtrips() {
        let e = ControlExport::default();
        e.publish(5, 17, 123.456);
        assert_eq!(e.sl_cap(), 5);
        assert_eq!(e.adjustments(), 17);
        assert!((e.goodput() - 123.456).abs() < 1e-3);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let ok = ControlConfig::default();
        assert!(ok.validate().is_ok());
        for bad in [
            ControlConfig { cap_max: 0, ..ok },
            ControlConfig { deadband: 1.0, ..ok },
            ControlConfig { hysteresis: 0, ..ok },
            ControlConfig {
                low_occupancy: 0.9,
                high_occupancy: 0.5,
                ..ok
            },
            ControlConfig {
                min_aggressiveness: 0.0,
                ..ok
            },
            ControlConfig { interval_ms: 0, ..ok },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn cap_bounds_property() {
        forall(
            83,
            200,
            |r| {
                let cap_max = r.range(1, 13);
                let ticks = r.range(1, 120);
                let stream: Vec<Vec<ReplicaSample>> = (0..ticks)
                    .map(|_| {
                        (0..r.range(1, 5))
                            .map(|_| ReplicaSample {
                                goodput: r.range(0, 200) as f64,
                                occupancy: r.range(0, 101) as f64 / 100.0,
                                queue: r.range(0, 20),
                                stale: r.chance(0.2),
                            })
                            .collect()
                    })
                    .collect();
                (cap_max, stream)
            },
            |(cap_max, stream)| {
                let mut c = Controller::new(ControlConfig {
                    cap_max: *cap_max,
                    ..Default::default()
                });
                for samples in stream {
                    let d = c.tick(samples);
                    if d.sl_cap < 1 || d.sl_cap > *cap_max {
                        return Err(format!(
                            "cap {} outside [1, {cap_max}]",
                            d.sl_cap
                        ));
                    }
                    if !ADMIT_LEVELS.contains(&d.admit_frac) {
                        return Err(format!("bad admit_frac {}", d.admit_frac));
                    }
                    for a in &d.aggressiveness {
                        if *a <= 0.0 || *a > 1.0 {
                            return Err(format!("aggressiveness {a} out of (0,1]"));
                        }
                    }
                }
                check(true, "")
            },
        );
    }
}
