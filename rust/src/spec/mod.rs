//! Speculative-decoding core: exact rejection sampling, signal computation,
//! per-sequence signal history, the SL adapters (the paper's contribution),
//! the adaptive SL-cap, and the fleet-level goodput feedback controller.

pub mod adapter;
pub mod cap;
pub mod control;
pub mod history;
pub mod kld;
pub mod rejection;
