//! Speculative-decoding core: exact rejection sampling, signal computation,
//! per-sequence signal history, the SL adapters (the paper's contribution),
//! and the adaptive SL-cap.

pub mod adapter;
pub mod cap;
pub mod history;
pub mod kld;
pub mod rejection;
