//! Speculative-decoding core: exact rejection sampling, signal computation,
//! per-sequence signal history, the SL adapters (the paper's contribution),
//! and the adaptive SL-cap.

pub mod adapter;
// The non-adapter submodules predate the crate-wide `missing_docs` lint;
// their public surfaces are documented opportunistically (ROADMAP: finish
// the sweep).
#[allow(missing_docs)]
pub mod cap;
#[allow(missing_docs)]
pub mod history;
#[allow(missing_docs)]
pub mod kld;
#[allow(missing_docs)]
pub mod rejection;
