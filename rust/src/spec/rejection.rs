//! Exact speculative rejection sampling (Leviathan et al. 2023 / Chen et
//! al. 2023) — vLLM's RejectionSampler equivalent.
//!
//! For each drafted token x_j with draft distribution q_j and target
//! distribution p_j:
//!   * accept with probability min(1, p_j(x_j) / q_j(x_j));
//!   * on rejection, emit a corrected token from the residual distribution
//!     norm(max(0, p_j − q_j)) and stop;
//!   * if all k tokens are accepted, emit one **bonus** token from the
//!     target's distribution at the position after the last draft token.
//!
//! This procedure provably samples each emitted token from the target
//! distribution — verified by the `exactness_*` property tests below.

use crate::spec::kld::softmax_t;
use crate::util::rng::Rng;

/// Outcome of verifying one sequence's drafted tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyOutcome {
    /// Tokens to append: accepted prefix + (correction | bonus).
    pub tokens: Vec<u32>,
    /// Number of draft tokens accepted (0..=k).
    pub accepted: usize,
    /// True iff all k drafts were accepted (the trailing token is a bonus).
    pub bonus: bool,
}

/// Rejection-sample one sequence.
///
/// * `draft_tokens[j]` — drafted token ids (len k).
/// * `draft_dists[j]` — draft probability distribution at slot j (len V).
/// * `target_dists[j]` — target distribution at slot j, for j in 0..=k — the
///   entry at k is the bonus position.
pub fn verify_sequence(
    rng: &mut Rng,
    draft_tokens: &[u32],
    draft_dists: &[Vec<f32>],
    target_dists: &[Vec<f32>],
) -> VerifyOutcome {
    let k = draft_tokens.len();
    assert_eq!(draft_dists.len(), k, "draft dists");
    assert!(target_dists.len() >= k + 1, "need k+1 target dists");
    let mut tokens = Vec::with_capacity(k + 1);
    for j in 0..k {
        let x = draft_tokens[j] as usize;
        let p = target_dists[j][x];
        let q = draft_dists[j][x].max(1e-12);
        let r = rng.f64() as f32;
        if r < (p / q).min(1.0) {
            tokens.push(draft_tokens[j]);
            continue;
        }
        // rejected: sample from residual norm(max(0, p - q))
        let tok = sample_residual(rng, &target_dists[j], &draft_dists[j]);
        tokens.push(tok);
        return VerifyOutcome {
            tokens,
            accepted: j,
            bonus: false,
        };
    }
    // all accepted: bonus token from the target's next-position distribution
    let bonus_tok = sample_dist(rng, &target_dists[k]);
    tokens.push(bonus_tok);
    VerifyOutcome {
        tokens,
        accepted: k,
        bonus: true,
    }
}

/// Sample from norm(max(0, p − q)); falls back to p if the residual has no
/// mass (possible only through numerical underflow).
pub fn sample_residual(rng: &mut Rng, p: &[f32], q: &[f32]) -> u32 {
    let mut total = 0.0f64;
    for (&pi, &qi) in p.iter().zip(q) {
        let d = (pi - qi).max(0.0);
        total += d as f64;
    }
    if total <= 1e-12 {
        return sample_dist(rng, p);
    }
    let mut t = rng.f64() * total;
    for (i, (&pi, &qi)) in p.iter().zip(q).enumerate() {
        let d = ((pi - qi).max(0.0)) as f64;
        t -= d;
        if t <= 0.0 {
            return i as u32;
        }
    }
    (p.len() - 1) as u32
}

/// Sample an index from a probability vector.
pub fn sample_dist(rng: &mut Rng, p: &[f32]) -> u32 {
    let mut t = rng.f64() as f32 * p.iter().sum::<f32>();
    for (i, &pi) in p.iter().enumerate() {
        t -= pi;
        if t <= 0.0 {
            return i as u32;
        }
    }
    (p.len() - 1) as u32
}

/// Theoretical per-token acceptance probability E_x~q[min(1, p/q)] =
/// 1 − TV(p, q).  Used by tests and the simulator calibration.
pub fn acceptance_prob(p: &[f32], q: &[f32]) -> f64 {
    let mut a = 0.0f64;
    for (&pi, &qi) in p.iter().zip(q) {
        a += (pi.min(qi)) as f64;
    }
    a
}

/// Convenience: greedy "rejection sampling" at temperature 0 — a draft
/// token is accepted iff it equals the target argmax; the correction/bonus
/// is the target argmax.  (This is the temp→0 limit of the exact sampler.)
pub fn verify_sequence_greedy(
    draft_tokens: &[u32],
    target_logits: &[&[f32]],
) -> VerifyOutcome {
    let k = draft_tokens.len();
    assert!(target_logits.len() >= k + 1);
    let mut tokens = Vec::with_capacity(k + 1);
    for j in 0..k {
        let am = crate::util::rng::argmax(target_logits[j]) as u32;
        if draft_tokens[j] == am {
            tokens.push(am);
        } else {
            tokens.push(am);
            return VerifyOutcome {
                tokens,
                accepted: j,
                bonus: false,
            };
        }
    }
    tokens.push(crate::util::rng::argmax(target_logits[k]) as u32);
    VerifyOutcome {
        tokens,
        accepted: k,
        bonus: true,
    }
}

/// Build a temperature-adjusted distribution from logits (helper shared by
/// the PJRT model wrapper).
pub fn dist_from_logits(logits: &[f32], temp: f64) -> Vec<f32> {
    softmax_t(logits, temp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};
    use crate::util::rng::Rng;

    fn random_dist(rng: &mut Rng, v: usize, sharp: f64) -> Vec<f32> {
        let logits: Vec<f32> = (0..v).map(|_| (rng.normal() * sharp) as f32).collect();
        softmax_t(&logits, 1.0)
    }

    #[test]
    fn accepts_when_distributions_match() {
        let mut rng = Rng::new(1);
        let v = 16;
        let p = random_dist(&mut rng, v, 2.0);
        // draft == target -> always accept
        let mut accepted = 0;
        for _ in 0..200 {
            let tok = sample_dist(&mut rng, &p);
            let out = verify_sequence(
                &mut rng,
                &[tok],
                &[p.clone()],
                &[p.clone(), p.clone()],
            );
            if out.accepted == 1 {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 200);
    }

    #[test]
    fn rejects_disjoint_supports() {
        let mut rng = Rng::new(2);
        let p = vec![0.0f32, 0.0, 0.5, 0.5];
        let q = vec![0.5f32, 0.5, 0.0, 0.0];
        for _ in 0..50 {
            let tok = sample_dist(&mut rng, &q);
            let out = verify_sequence(
                &mut rng,
                &[tok],
                &[q.clone()],
                &[p.clone(), p.clone()],
            );
            assert_eq!(out.accepted, 0);
            assert!(out.tokens[0] >= 2, "correction must come from target support");
        }
    }

    #[test]
    fn bonus_emitted_on_full_acceptance() {
        let mut rng = Rng::new(3);
        let p = vec![1.0f32, 0.0];
        let out = verify_sequence(
            &mut rng,
            &[0, 0, 0],
            &[p.clone(), p.clone(), p.clone()],
            &[p.clone(), p.clone(), p.clone(), p.clone()],
        );
        assert_eq!(out.accepted, 3);
        assert!(out.bonus);
        assert_eq!(out.tokens, vec![0, 0, 0, 0]);
    }

    #[test]
    fn acceptance_prob_is_one_minus_tv() {
        let p = vec![0.6f32, 0.4, 0.0];
        let q = vec![0.2f32, 0.4, 0.4];
        // TV = 0.5 * (0.4 + 0 + 0.4) = 0.4 -> acceptance 0.6
        assert!((acceptance_prob(&p, &q) - 0.6).abs() < 1e-6);
    }

    /// The core exactness property: for arbitrary draft/target pairs, the
    /// distribution of the FIRST emitted token equals the target
    /// distribution p_0 (chi-square-style tolerance over many trials).
    #[test]
    fn exactness_first_token_matches_target() {
        forall(
            11,
            8,
            |r| {
                let v = 8;
                (random_dist(r, v, 1.5), random_dist(r, v, 1.5))
            },
            |(p, q)| {
                let mut rng = Rng::new(99);
                let v = p.len();
                let trials = 30_000;
                let mut counts = vec![0usize; v];
                for _ in 0..trials {
                    let tok = sample_dist(&mut rng, q);
                    let out = verify_sequence(
                        &mut rng,
                        &[tok],
                        &[q.clone()],
                        &[p.clone(), p.clone()],
                    );
                    counts[out.tokens[0] as usize] += 1;
                }
                for i in 0..v {
                    let emp = counts[i] as f64 / trials as f64;
                    let expect = p[i] as f64;
                    let se = (expect * (1.0 - expect) / trials as f64).sqrt();
                    if (emp - expect).abs() > 6.0 * se + 0.003 {
                        return Err(format!(
                            "token {i}: empirical {emp:.4} vs target {expect:.4}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Acceptance *rate* must match 1 − TV(p, q).
    #[test]
    fn exactness_acceptance_rate() {
        forall(
            13,
            6,
            |r| (random_dist(r, 12, 2.0), random_dist(r, 12, 2.0)),
            |(p, q)| {
                let mut rng = Rng::new(7);
                let trials = 20_000;
                let mut acc = 0usize;
                for _ in 0..trials {
                    let tok = sample_dist(&mut rng, q);
                    let out = verify_sequence(
                        &mut rng,
                        &[tok],
                        &[q.clone()],
                        &[p.clone(), p.clone()],
                    );
                    acc += out.accepted;
                }
                let emp = acc as f64 / trials as f64;
                let expect = acceptance_prob(p, q);
                check(
                    (emp - expect).abs() < 0.02,
                    format!("acceptance {emp:.4} vs expected {expect:.4}"),
                )
            },
        );
    }

    #[test]
    fn multi_token_stops_at_first_rejection() {
        let mut rng = Rng::new(5);
        let p_accept = vec![1.0f32, 0.0];
        let p_reject = vec![0.0f32, 1.0];
        // draft always proposes token 0; slot 1 target mass is on token 1
        let out = verify_sequence(
            &mut rng,
            &[0, 0, 0],
            &[p_accept.clone(), p_accept.clone(), p_accept.clone()],
            &[
                p_accept.clone(),
                p_reject.clone(),
                p_accept.clone(),
                p_accept.clone(),
            ],
        );
        assert_eq!(out.accepted, 1);
        assert!(!out.bonus);
        assert_eq!(out.tokens, vec![0, 1]); // accepted, then correction
    }

    #[test]
    fn greedy_verify_matches_argmax_chain() {
        let t0 = [0.1f32, 0.9];
        let t1 = [0.8f32, 0.2];
        let t2 = [0.3f32, 0.7];
        let out = verify_sequence_greedy(&[1, 0], &[&t0, &t1, &t2]);
        assert_eq!(out.accepted, 2);
        assert!(out.bonus);
        assert_eq!(out.tokens, vec![1, 0, 1]);
        let out2 = verify_sequence_greedy(&[1, 1], &[&t0, &t1, &t2]);
        assert_eq!(out2.accepted, 1);
        assert_eq!(out2.tokens, vec![1, 0]);
    }

    #[test]
    fn residual_sampler_only_emits_positive_residual() {
        let mut rng = Rng::new(17);
        let p = vec![0.5f32, 0.3, 0.2, 0.0];
        let q = vec![0.6f32, 0.1, 0.1, 0.2];
        // residual support: {1, 2}
        for _ in 0..500 {
            let t = sample_residual(&mut rng, &p, &q);
            assert!(t == 1 || t == 2, "got {t}");
        }
    }

    #[test]
    fn sample_dist_covers_support() {
        let mut rng = Rng::new(19);
        let p = vec![0.25f32; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample_dist(&mut rng, &p) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
