//! Adaptive speculative-length capping (paper §3.3) — the straggler-problem
//! mitigation.  In batched per-sequence decoding the round cost follows
//! `max_i SL_i`, so a single aggressive prediction stalls the whole batch.
//! The paper frames the cap as the minimizer of the MSE between one shared
//! cap and the individual predictions (Eq. 9–10), which is the batch mean
//! (Eq. 11).  Alternative consensus functions are provided for the ablation
//! bench (`fig9_scalability --cap-mode ...`).

use crate::spec::control::ControlView;
use crate::util::stats::percentile;

/// Consensus function for the per-batch cap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapMode {
    /// No cap — naive per-sequence decoding (the paper's "No Cap" series).
    None,
    /// Paper Eq. 11: arithmetic mean of the predictions (MSE minimizer).
    Mean,
    /// Median of the predictions (robust-consensus ablation).
    Median,
    /// 90th percentile (loose-cap ablation).
    P90,
}

impl CapMode {
    /// Parse CLI shorthand: `none`/`no-cap`, `mean`, `median`, or `p90`.
    pub fn parse(s: &str) -> Option<CapMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "nocap" | "no-cap" => Some(CapMode::None),
            "mean" => Some(CapMode::Mean),
            "median" => Some(CapMode::Median),
            "p90" => Some(CapMode::P90),
            _ => None,
        }
    }

    /// Stable lowercase wire/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            CapMode::None => "none",
            CapMode::Mean => "mean",
            CapMode::Median => "median",
            CapMode::P90 => "p90",
        }
    }
}

/// Compute the batch cap for the given per-sequence predictions.  Returns
/// `usize::MAX` for [`CapMode::None`] (i.e. no constraint).  The mean is
/// rounded up: `ceil` keeps the cap from starving a homogeneous batch whose
/// predictions all sit at x.5 after integer prediction.
pub fn compute_cap(mode: CapMode, predictions: &[usize]) -> usize {
    if predictions.is_empty() {
        return usize::MAX;
    }
    let xs: Vec<f64> = predictions.iter().map(|&x| x as f64).collect();
    match mode {
        CapMode::None => usize::MAX,
        CapMode::Mean => {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            m.ceil() as usize
        }
        CapMode::Median => percentile(&xs, 0.5).round() as usize,
        CapMode::P90 => percentile(&xs, 0.9).ceil() as usize,
    }
}

/// Apply the cap: `SL_i ← min(SL_i, cap)`, preserving a floor of 1 so a
/// pathological cap of 0 cannot disable speculation entirely.
pub fn apply_cap(mode: CapMode, predictions: &mut [usize]) -> usize {
    let cap = compute_cap(mode, predictions).max(1);
    for p in predictions.iter_mut() {
        *p = (*p).min(cap);
    }
    cap
}

/// Deadline slack fraction below which a deadline-carrying sequence is
/// clamped to a conservative SL of 2: with under ~a third of the budget
/// left, a deep failed speculation costs latency the deadline cannot
/// absorb.
pub const TIGHT_SLACK_FRAC: f64 = 0.35;

/// Deadline slack fraction below which the clamp tightens to SL 1 (the
/// request is about to breach — pay only the cheapest speculation).
pub const CRITICAL_SLACK_FRAC: f64 = 0.15;

/// Trade speculation depth against deadline slack (applied after the batch
/// cap and controller throttle): sequences whose remaining deadline budget
/// has degraded below [`TIGHT_SLACK_FRAC`] are clamped to SL 2, below
/// [`CRITICAL_SLACK_FRAC`] to SL 1, while slack sequences keep whatever the
/// cap granted.  `slack[i]` is [`deadline_slack_frac`] for sequence `i`
/// (`None` = no deadline).  A batch with no deadlines is an exact identity,
/// which keeps pre-tenancy traffic bit-identical.  Returns the number of
/// sequences clamped.
///
/// [`deadline_slack_frac`]: crate::engine::request::SeqState::deadline_slack_frac
pub fn apply_deadline_slack(sls: &mut [usize], slack: &[Option<f64>]) -> usize {
    let mut clamped = 0;
    for (sl, s) in sls.iter_mut().zip(slack) {
        let Some(frac) = s else { continue };
        let bound = if *frac < CRITICAL_SLACK_FRAC {
            1
        } else if *frac < TIGHT_SLACK_FRAC {
            2
        } else {
            continue;
        };
        if *sl > bound {
            *sl = bound;
            clamped += 1;
        }
    }
    clamped
}

/// Fold the fleet controller's actuators into the granted SLs (after the
/// batch-consensus cap): scale every SL by the replica's aggressiveness
/// multiplier, then clamp to the controller's global cap, preserving the
/// same floor of 1 as [`apply_cap`].  A neutral
/// [`ControlView`] (`sl_cap = usize::MAX`, `aggressiveness = 1.0`) is an
/// exact identity, which is what keeps `--spec-control off` bit-identical
/// to a build with no controller at all.
pub fn apply_control(view: &ControlView, predictions: &mut [usize]) -> usize {
    let cap = view.sl_cap.max(1);
    for p in predictions.iter_mut() {
        let scaled = ((*p as f64) * view.aggressiveness).floor() as usize;
        *p = scaled.clamp(1, cap);
    }
    cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};

    #[test]
    fn mean_cap_is_mse_minimizer() {
        // Eq. 9-11: the cap minimizing sum (cap - sl_i)^2 is the mean.
        let preds = [4usize, 2, 3, 1];
        let cap = compute_cap(CapMode::Mean, &preds);
        let mse = |c: f64| -> f64 {
            preds.iter().map(|&p| (c - p as f64).powi(2)).sum::<f64>() / preds.len() as f64
        };
        let exact_mean = 2.5;
        assert!(mse(exact_mean) <= mse(2.0) && mse(exact_mean) <= mse(4.0));
        assert_eq!(cap, 3); // ceil(2.5)
    }

    #[test]
    fn none_mode_is_unbounded() {
        assert_eq!(compute_cap(CapMode::None, &[1, 12, 3]), usize::MAX);
    }

    #[test]
    fn cap_tames_outlier() {
        let mut preds = vec![2usize, 2, 2, 12];
        let cap = apply_cap(CapMode::Mean, &mut preds);
        assert_eq!(cap, 5); // ceil(4.5)
        assert_eq!(preds, vec![2, 2, 2, 5]);
    }

    #[test]
    fn median_robust_to_outlier() {
        let mut preds = vec![2usize, 2, 2, 12];
        let cap = apply_cap(CapMode::Median, &mut preds);
        assert_eq!(cap, 2);
        assert_eq!(preds, vec![2, 2, 2, 2]);
    }

    #[test]
    fn p90_is_loose() {
        let preds = vec![2usize, 2, 2, 2, 2, 2, 2, 2, 2, 12];
        let cap = compute_cap(CapMode::P90, &preds);
        assert!(cap >= 3 && cap <= 12);
    }

    #[test]
    fn empty_predictions_unbounded() {
        assert_eq!(compute_cap(CapMode::Mean, &[]), usize::MAX);
    }

    #[test]
    fn parse_roundtrip() {
        for m in [CapMode::None, CapMode::Mean, CapMode::Median, CapMode::P90] {
            assert_eq!(CapMode::parse(m.name()), Some(m));
        }
        assert_eq!(CapMode::parse("bogus"), None);
    }

    #[test]
    fn neutral_control_view_is_identity() {
        let mut preds = vec![1usize, 3, 7, 12];
        let before = preds.clone();
        apply_control(&ControlView::default(), &mut preds);
        assert_eq!(preds, before);
    }

    #[test]
    fn control_cap_and_aggressiveness_compose() {
        let mut preds = vec![2usize, 6, 12];
        let view = ControlView {
            sl_cap: 4,
            admit_frac: 1.0,
            aggressiveness: 0.5,
        };
        let cap = apply_control(&view, &mut preds);
        assert_eq!(cap, 4);
        // floor(sl * 0.5) clamped to [1, 4]
        assert_eq!(preds, vec![1, 3, 4]);
    }

    #[test]
    fn control_never_zeroes_speculation() {
        let mut preds = vec![1usize, 2];
        let view = ControlView {
            sl_cap: 1,
            admit_frac: 0.5,
            aggressiveness: 0.25,
        };
        apply_control(&view, &mut preds);
        assert_eq!(preds, vec![1, 1], "floor of 1 survives the throttle");
    }

    #[test]
    fn deadline_slack_identity_without_deadlines() {
        let mut sls = vec![1usize, 4, 9, 12];
        let before = sls.clone();
        let clamped = apply_deadline_slack(&mut sls, &[None, None, None, None]);
        assert_eq!(clamped, 0);
        assert_eq!(sls, before, "no deadlines -> exact identity");
    }

    #[test]
    fn deadline_slack_tiers_clamp_tight_sequences() {
        let mut sls = vec![8usize, 8, 8, 8];
        let slack = [Some(0.9), Some(0.3), Some(0.1), Some(-0.5)];
        let clamped = apply_deadline_slack(&mut sls, &slack);
        assert_eq!(clamped, 3);
        assert_eq!(sls, vec![8, 2, 1, 1], "slack keeps, tight 2, critical 1");
        // already-conservative SLs are not counted as clamps
        let mut low = vec![1usize, 2];
        let n = apply_deadline_slack(&mut low, &[Some(0.0), Some(0.2)]);
        assert_eq!(n, 0);
        assert_eq!(low, vec![1, 2]);
    }

    #[test]
    fn deadline_slack_never_raises_property() {
        forall(
            73,
            300,
            |r| {
                let n = r.range(1, 33);
                let sls: Vec<usize> = (0..n).map(|_| r.range(1, 13)).collect();
                let slack: Vec<Option<f64>> = (0..n)
                    .map(|_| {
                        if r.range(0, 2) == 0 {
                            None
                        } else {
                            Some(r.range(0, 201) as f64 / 100.0 - 1.0)
                        }
                    })
                    .collect();
                (sls, slack)
            },
            |(sls, slack)| {
                let mut out = sls.clone();
                apply_deadline_slack(&mut out, slack);
                for (i, (c, o)) in out.iter().zip(sls).enumerate() {
                    if c > o {
                        return Err(format!("clamp raised {o} -> {c}"));
                    }
                    if *c == 0 {
                        return Err("clamped to zero".into());
                    }
                    if slack[i].is_none() && c != o {
                        return Err(format!("no-deadline seq {i} changed"));
                    }
                }
                check(true, "")
            },
        );
    }

    #[test]
    fn control_invariants_property() {
        forall(
            59,
            300,
            |r| {
                let n = r.range(1, 33);
                let preds: Vec<usize> = (0..n).map(|_| r.range(1, 13)).collect();
                let view = ControlView {
                    sl_cap: r.range(1, 14),
                    admit_frac: 1.0,
                    aggressiveness: r.range(1, 101) as f64 / 100.0,
                };
                (preds, view)
            },
            |(preds, view)| {
                let mut out = preds.clone();
                apply_control(view, &mut out);
                for (c, o) in out.iter().zip(preds) {
                    if c > o {
                        return Err(format!("control raised {o} -> {c}"));
                    }
                    if *c == 0 || *c > view.sl_cap.max(1) {
                        return Err(format!("{c} outside [1, {}]", view.sl_cap));
                    }
                }
                check(true, "")
            },
        );
    }

    #[test]
    fn cap_invariants_property() {
        forall(
            41,
            300,
            |r| {
                let n = r.range(1, 65);
                let preds: Vec<usize> = (0..n).map(|_| r.range(1, 13)).collect();
                let mode = [CapMode::None, CapMode::Mean, CapMode::Median, CapMode::P90]
                    [r.range(0, 4)];
                (preds, mode)
            },
            |(preds, mode)| {
                let mut capped = preds.clone();
                let cap = apply_cap(*mode, &mut capped);
                let max_in = *preds.iter().max().unwrap();
                let min_in = *preds.iter().min().unwrap();
                // capped values never exceed originals and never below 1
                for (c, o) in capped.iter().zip(preds) {
                    if c > o {
                        return Err(format!("cap raised {o} -> {c}"));
                    }
                    if *c == 0 {
                        return Err("capped to zero".into());
                    }
                }
                // cap lies within [min, max] of predictions (or MAX for None)
                if *mode != CapMode::None && !(min_in..=max_in).contains(&cap.min(max_in)) {
                    return Err(format!("cap {cap} outside [{min_in}, {max_in}]"));
                }
                check(true, "")
            },
        );
    }
}
