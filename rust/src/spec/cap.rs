//! Adaptive speculative-length capping (paper §3.3) — the straggler-problem
//! mitigation.  In batched per-sequence decoding the round cost follows
//! `max_i SL_i`, so a single aggressive prediction stalls the whole batch.
//! The paper frames the cap as the minimizer of the MSE between one shared
//! cap and the individual predictions (Eq. 9–10), which is the batch mean
//! (Eq. 11).  Alternative consensus functions are provided for the ablation
//! bench (`fig9_scalability --cap-mode ...`).

use crate::util::stats::percentile;

/// Consensus function for the per-batch cap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapMode {
    /// No cap — naive per-sequence decoding (the paper's "No Cap" series).
    None,
    /// Paper Eq. 11: arithmetic mean of the predictions (MSE minimizer).
    Mean,
    /// Median of the predictions (robust-consensus ablation).
    Median,
    /// 90th percentile (loose-cap ablation).
    P90,
}

impl CapMode {
    /// Parse CLI shorthand: `none`/`no-cap`, `mean`, `median`, or `p90`.
    pub fn parse(s: &str) -> Option<CapMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "nocap" | "no-cap" => Some(CapMode::None),
            "mean" => Some(CapMode::Mean),
            "median" => Some(CapMode::Median),
            "p90" => Some(CapMode::P90),
            _ => None,
        }
    }

    /// Stable lowercase wire/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            CapMode::None => "none",
            CapMode::Mean => "mean",
            CapMode::Median => "median",
            CapMode::P90 => "p90",
        }
    }
}

/// Compute the batch cap for the given per-sequence predictions.  Returns
/// `usize::MAX` for [`CapMode::None`] (i.e. no constraint).  The mean is
/// rounded up: `ceil` keeps the cap from starving a homogeneous batch whose
/// predictions all sit at x.5 after integer prediction.
pub fn compute_cap(mode: CapMode, predictions: &[usize]) -> usize {
    if predictions.is_empty() {
        return usize::MAX;
    }
    let xs: Vec<f64> = predictions.iter().map(|&x| x as f64).collect();
    match mode {
        CapMode::None => usize::MAX,
        CapMode::Mean => {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            m.ceil() as usize
        }
        CapMode::Median => percentile(&xs, 0.5).round() as usize,
        CapMode::P90 => percentile(&xs, 0.9).ceil() as usize,
    }
}

/// Apply the cap: `SL_i ← min(SL_i, cap)`, preserving a floor of 1 so a
/// pathological cap of 0 cannot disable speculation entirely.
pub fn apply_cap(mode: CapMode, predictions: &mut [usize]) -> usize {
    let cap = compute_cap(mode, predictions).max(1);
    for p in predictions.iter_mut() {
        *p = (*p).min(cap);
    }
    cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};

    #[test]
    fn mean_cap_is_mse_minimizer() {
        // Eq. 9-11: the cap minimizing sum (cap - sl_i)^2 is the mean.
        let preds = [4usize, 2, 3, 1];
        let cap = compute_cap(CapMode::Mean, &preds);
        let mse = |c: f64| -> f64 {
            preds.iter().map(|&p| (c - p as f64).powi(2)).sum::<f64>() / preds.len() as f64
        };
        let exact_mean = 2.5;
        assert!(mse(exact_mean) <= mse(2.0) && mse(exact_mean) <= mse(4.0));
        assert_eq!(cap, 3); // ceil(2.5)
    }

    #[test]
    fn none_mode_is_unbounded() {
        assert_eq!(compute_cap(CapMode::None, &[1, 12, 3]), usize::MAX);
    }

    #[test]
    fn cap_tames_outlier() {
        let mut preds = vec![2usize, 2, 2, 12];
        let cap = apply_cap(CapMode::Mean, &mut preds);
        assert_eq!(cap, 5); // ceil(4.5)
        assert_eq!(preds, vec![2, 2, 2, 5]);
    }

    #[test]
    fn median_robust_to_outlier() {
        let mut preds = vec![2usize, 2, 2, 12];
        let cap = apply_cap(CapMode::Median, &mut preds);
        assert_eq!(cap, 2);
        assert_eq!(preds, vec![2, 2, 2, 2]);
    }

    #[test]
    fn p90_is_loose() {
        let preds = vec![2usize, 2, 2, 2, 2, 2, 2, 2, 2, 12];
        let cap = compute_cap(CapMode::P90, &preds);
        assert!(cap >= 3 && cap <= 12);
    }

    #[test]
    fn empty_predictions_unbounded() {
        assert_eq!(compute_cap(CapMode::Mean, &[]), usize::MAX);
    }

    #[test]
    fn parse_roundtrip() {
        for m in [CapMode::None, CapMode::Mean, CapMode::Median, CapMode::P90] {
            assert_eq!(CapMode::parse(m.name()), Some(m));
        }
        assert_eq!(CapMode::parse("bogus"), None);
    }

    #[test]
    fn cap_invariants_property() {
        forall(
            41,
            300,
            |r| {
                let n = r.range(1, 65);
                let preds: Vec<usize> = (0..n).map(|_| r.range(1, 13)).collect();
                let mode = [CapMode::None, CapMode::Mean, CapMode::Median, CapMode::P90]
                    [r.range(0, 4)];
                (preds, mode)
            },
            |(preds, mode)| {
                let mut capped = preds.clone();
                let cap = apply_cap(*mode, &mut capped);
                let max_in = *preds.iter().max().unwrap();
                let min_in = *preds.iter().min().unwrap();
                // capped values never exceed originals and never below 1
                for (c, o) in capped.iter().zip(preds) {
                    if c > o {
                        return Err(format!("cap raised {o} -> {c}"));
                    }
                    if *c == 0 {
                        return Err("capped to zero".into());
                    }
                }
                // cap lies within [min, max] of predictions (or MAX for None)
                if *mode != CapMode::None && !(min_in..=max_in).contains(&cap.min(max_in)) {
                    return Err(format!("cap {cap} outside [{min_in}, {max_in}]"));
                }
                check(true, "")
            },
        );
    }
}
