//! The DSDE SL-Adapter (paper §3.1) — training-free, per-sequence,
//! per-iteration speculation-length prediction from post-hoc KLD stability.
//!
//! * **Calibration (Eq. 1)** — for the first `calib_steps` speculative steps
//!   of a sequence the engine drafts with `calib_sl` and records per-token
//!   KLDs + acceptance; afterwards
//!   `SL_max = SL_{A,max} · (1 + μ_KLD,pre / (KLD_pre,max + ε))`.
//! * **Prediction (Eq. 2–8)** — `SL̂ = (1 − SF·WVIR)·(SL_max − SL_min) +
//!   SL_min` with `SF = exp(2·μ_KLD,last) − 1` (Eq. 3) and
//!   `WVIR = Var_w(KLD_short)/Var_w(KLD_long)` (Eq. 4, weights Eq. 5–7);
//!   when the penalty exceeds 1 the prediction clamps to `SL_min` (Eq. 8).

use super::SlPolicy;
use crate::spec::history::SeqSignals;

/// DSDE adapter configuration (paper defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct DsdeConfig {
    /// SL_min — pre-set minimum speculation length (paper: 2).
    pub sl_min: usize,
    /// Hard ceiling from the artifact interface (verify graph's K).
    pub sl_limit: usize,
    /// Number of preliminary calibration steps per sequence.
    pub calib_steps: usize,
    /// SL used while calibrating.
    pub calib_sl: usize,
    /// ε of Eq. 1.
    pub epsilon: f64,
    /// Coefficient in SF = exp(c·μ_KLD,last) − 1 (paper: 2).
    pub sf_coeff: f64,
}

impl Default for DsdeConfig {
    fn default() -> Self {
        DsdeConfig {
            sl_min: 2,
            sl_limit: 12,
            calib_steps: 4,
            // calibrate with long drafts so SL_{A,max} (Eq. 1) can observe
            // the model pair's true capability, not the probe length
            calib_sl: 10,
            epsilon: 1e-6,
            sf_coeff: 2.0,
        }
    }
}

/// See module docs.
#[derive(Clone, Debug)]
pub struct DsdeAdapter {
    cfg: DsdeConfig,
}

impl DsdeAdapter {
    /// Construct from config.
    pub fn new(cfg: DsdeConfig) -> DsdeAdapter {
        DsdeAdapter { cfg }
    }

    /// The adapter's configuration.
    pub fn config(&self) -> &DsdeConfig {
        &self.cfg
    }

    /// Eq. 1 — data-informed SL_max from the calibration statistics.
    pub fn calibrated_sl_max(&self, sig: &SeqSignals) -> usize {
        let sl_a_max = sig.calib_max_accepted.max(self.cfg.sl_min);
        let ratio = sig.calib_mean_kld() / (sig.calib_kld_max + self.cfg.epsilon);
        let sl_max = (sl_a_max as f64 * (1.0 + ratio)).round() as usize;
        sl_max.clamp(self.cfg.sl_min, self.cfg.sl_limit)
    }

    /// Eq. 3 — scale factor from the most recent step's mean KLD.
    pub fn scale_factor(&self, sig: &SeqSignals) -> f64 {
        (self.cfg.sf_coeff * sig.last_step_mean_kld).exp() - 1.0
    }

    /// Eq. 2/8 — the SL prediction.
    pub fn predict(&self, sig: &SeqSignals) -> usize {
        let sl_max = sig
            .calibrated_sl_max
            .unwrap_or(self.cfg.sl_limit)
            .clamp(self.cfg.sl_min, self.cfg.sl_limit);
        let delta = (sl_max - self.cfg.sl_min) as f64;
        let penalty = self.scale_factor(sig) * sig.wvir();
        if penalty >= 1.0 {
            // Eq. 8: extreme instability -> most conservative strategy
            return self.cfg.sl_min;
        }
        let sl_hat = (1.0 - penalty) * delta + self.cfg.sl_min as f64;
        (sl_hat.round() as usize).clamp(self.cfg.sl_min, sl_max)
    }
}

impl SlPolicy for DsdeAdapter {
    fn name(&self) -> &'static str {
        "dsde"
    }

    fn propose(&self, sig: &SeqSignals) -> usize {
        if sig.calibrated_sl_max.is_none() && sig.steps < self.cfg.calib_steps {
            return self.cfg.calib_sl.clamp(self.cfg.sl_min, self.cfg.sl_limit);
        }
        self.predict(sig)
    }

    fn wants_calibration(&self) -> bool {
        true
    }

    fn calibration_steps(&self) -> usize {
        self.cfg.calib_steps
    }

    fn finish_calibration(&self, sig: &mut SeqSignals) {
        sig.calibrated_sl_max = Some(self.calibrated_sl_max(sig));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::history::{HistoryConfig, SeqSignals};
    use crate::util::proptest::{check, forall};
    use crate::util::rng::Rng;

    fn signals_with(klds: &[f64], accepted: usize, drafted: usize) -> SeqSignals {
        let mut s = SeqSignals::new(HistoryConfig::default());
        for &k in klds {
            s.record_step(&[k as f32], &[0.5], drafted, accepted);
        }
        s
    }

    #[test]
    fn calibration_formula_eq1() {
        let a = DsdeAdapter::new(DsdeConfig::default());
        let mut s = SeqSignals::default();
        // SL_A,max = 6, μ = (1+3)/2 = 2, max = 3 -> 6 * (1 + 2/3) = 10
        s.record_calibration(&[1.0], 6);
        s.record_calibration(&[3.0], 2);
        assert_eq!(a.calibrated_sl_max(&s), 10);
    }

    #[test]
    fn calibration_clamps_to_limit() {
        let a = DsdeAdapter::new(DsdeConfig {
            sl_limit: 8,
            ..Default::default()
        });
        let mut s = SeqSignals::default();
        s.record_calibration(&[5.0, 5.0], 8); // ratio -> ~2x
        assert_eq!(a.calibrated_sl_max(&s), 8);
    }

    #[test]
    fn zero_kld_gives_max_length() {
        // perfectly agreeing models: SF = 0 -> SL = SL_max
        let a = DsdeAdapter::new(DsdeConfig::default());
        let mut s = signals_with(&[0.0; 30], 4, 4);
        s.calibrated_sl_max = Some(10);
        assert_eq!(a.predict(&s), 10);
    }

    #[test]
    fn high_kld_collapses_to_min() {
        let a = DsdeAdapter::new(DsdeConfig::default());
        let mut s = signals_with(&[3.0; 30], 0, 4);
        s.calibrated_sl_max = Some(10);
        // SF = e^6 - 1 >> 1 -> Eq. 8 clamp
        assert_eq!(a.predict(&s), 2);
    }

    #[test]
    fn instability_increases_penalty() {
        let a = DsdeAdapter::new(DsdeConfig::default());
        let mut stable = signals_with(&[0.12; 30], 4, 4);
        stable.calibrated_sl_max = Some(12);

        // identical LAST-step KLD (same SF), but a volatile recent window:
        // WVIR > 1 must raise the penalty and never raise the prediction.
        // (With the paper's δ = 0.85 the WVIR modulation is mild — exactly
        // why Table 2 reports a tiny token-level correlation for it — so we
        // assert on the penalty term and a non-strict SL relation.)
        let mut vol = SeqSignals::default();
        for _ in 0..20 {
            vol.record_step(&[0.12], &[0.5], 4, 2);
        }
        for k in [1.4f32, 0.02, 1.6, 0.05, 1.2, 0.1, 1.5, 0.05, 1.3, 0.12] {
            vol.record_step(&[k], &[0.5], 4, 2);
        }
        vol.calibrated_sl_max = Some(12);

        assert_eq!(stable.last_step_mean_kld, 0.12f32 as f64);
        assert_eq!(vol.last_step_mean_kld, 0.12f32 as f64);
        let pen_stable = a.scale_factor(&stable) * stable.wvir();
        let pen_vol = a.scale_factor(&vol) * vol.wvir();
        assert!(
            pen_vol > pen_stable,
            "volatile penalty {pen_vol:.4} should exceed stable {pen_stable:.4}"
        );
        assert!(a.predict(&vol) <= a.predict(&stable));
    }

    #[test]
    fn proposes_calib_sl_during_calibration() {
        let a = DsdeAdapter::new(DsdeConfig::default());
        let s = SeqSignals::default();
        assert_eq!(a.propose(&s), 10);
        assert!(a.wants_calibration());
    }

    #[test]
    fn prediction_always_within_bounds_property() {
        let cfg = DsdeConfig::default();
        let a = DsdeAdapter::new(cfg.clone());
        forall(
            31,
            200,
            |r: &mut Rng| {
                let mut s = SeqSignals::default();
                let n = r.range(0, 40);
                for _ in 0..n {
                    let kld = r.f64() * 4.0;
                    let drafted = r.range(1, 13);
                    let acc = r.range(0, drafted + 1);
                    s.record_step(&[kld as f32], &[0.5], drafted, acc);
                }
                if r.chance(0.7) {
                    s.calibrated_sl_max = Some(r.range(2, 13));
                }
                let sl = a.propose(&s);
                (n, sl)
            },
            |&(_, sl)| {
                check(
                    (cfg.sl_min..=cfg.sl_limit).contains(&sl),
                    format!("SL {sl} out of [{}, {}]", cfg.sl_min, cfg.sl_limit),
                )
            },
        );
    }

    #[test]
    fn sf_is_zero_at_zero_kld_and_grows() {
        let a = DsdeAdapter::new(DsdeConfig::default());
        let s0 = signals_with(&[0.0], 1, 1);
        assert!(a.scale_factor(&s0).abs() < 1e-12);
        let s1 = signals_with(&[0.5], 1, 1);
        let s2 = signals_with(&[1.0], 1, 1);
        assert!(a.scale_factor(&s2) > a.scale_factor(&s1));
    }
}
