//! Adapter ablations + extensions.
//!
//! The paper's conclusion calls out two directions we implement here so the
//! ablation bench can quantify them:
//! * **component ablations** of the DSDE penalty — SF-only (drop WVIR) and
//!   WVIR-only (drop SF) — isolating how much each signal contributes;
//! * **the "optionally combined with entropy" variant** (§1 contribution
//!   list): DSDE's post-hoc penalty blended with a forward-looking
//!   entropy-based early-stop, getting both failure modes covered;
//! * an **oracle** policy (upper bound): proposes exactly the number of
//!   tokens that will be accepted next round — unrealizable online, used to
//!   bound how much headroom any predictor has left.

use super::dsde::{DsdeAdapter, DsdeConfig};
use super::SlPolicy;
use crate::spec::history::SeqSignals;

/// Which part of the DSDE penalty to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DsdeVariant {
    /// Full penalty SF·WVIR (the paper's Eq. 2).
    Full,
    /// SF only (immediate disagreement, no stability history).
    SfOnly,
    /// WVIR only (stability history, no immediate level).
    WvirOnly,
}

/// DSDE with an ablated penalty term.
#[derive(Clone, Debug)]
pub struct DsdeAblated {
    inner: DsdeAdapter,
    variant: DsdeVariant,
}

impl DsdeAblated {
    /// Construct a DSDE adapter with the given penalty ablation.
    pub fn new(cfg: DsdeConfig, variant: DsdeVariant) -> DsdeAblated {
        DsdeAblated {
            inner: DsdeAdapter::new(cfg),
            variant,
        }
    }

    fn penalty(&self, sig: &SeqSignals) -> f64 {
        match self.variant {
            DsdeVariant::Full => self.inner.scale_factor(sig) * sig.wvir(),
            DsdeVariant::SfOnly => self.inner.scale_factor(sig),
            // WVIR fluctuates around 1; recenter so stable ≈ no penalty
            DsdeVariant::WvirOnly => (sig.wvir() - 1.0).max(0.0),
        }
    }
}

impl SlPolicy for DsdeAblated {
    fn name(&self) -> &'static str {
        match self.variant {
            DsdeVariant::Full => "dsde",
            DsdeVariant::SfOnly => "dsde-sf-only",
            DsdeVariant::WvirOnly => "dsde-wvir-only",
        }
    }

    fn propose(&self, sig: &SeqSignals) -> usize {
        let cfg = self.inner.config();
        if sig.calibrated_sl_max.is_none() && sig.steps < cfg.calib_steps {
            return cfg.calib_sl.clamp(cfg.sl_min, cfg.sl_limit);
        }
        let sl_max = sig
            .calibrated_sl_max
            .unwrap_or(cfg.sl_limit)
            .clamp(cfg.sl_min, cfg.sl_limit);
        let delta = (sl_max - cfg.sl_min) as f64;
        let penalty = self.penalty(sig);
        if penalty >= 1.0 {
            return cfg.sl_min;
        }
        let sl_hat = (1.0 - penalty) * delta + cfg.sl_min as f64;
        (sl_hat.round() as usize).clamp(cfg.sl_min, sl_max)
    }

    fn wants_calibration(&self) -> bool {
        true
    }

    fn calibration_steps(&self) -> usize {
        self.inner.config().calib_steps
    }

    fn finish_calibration(&self, sig: &mut SeqSignals) {
        sig.calibrated_sl_max = Some(self.inner.calibrated_sl_max(sig));
    }
}

/// DSDE + entropy early-stop: the paper's "optionally combined with
/// entropy" extension.  Proposes with the full DSDE rule but additionally
/// stops drafting early when the draft's forward-looking entropy signals a
/// likely rejection (AdaEDL-style bound), so a stale regional signal can't
/// overdraft into a fresh difficulty spike.
#[derive(Clone, Debug)]
pub struct DsdeEntropy {
    inner: DsdeAdapter,
    /// entropy-bound coefficient (λ of the acceptance lower bound)
    pub lambda: f64,
    /// stop threshold scale on the historical acceptance EWMA
    pub theta: f64,
}

impl DsdeEntropy {
    /// Construct from the DSDE config plus the entropy-stop parameters.
    pub fn new(cfg: DsdeConfig, lambda: f64, theta: f64) -> DsdeEntropy {
        DsdeEntropy {
            inner: DsdeAdapter::new(cfg),
            lambda,
            theta,
        }
    }
}

impl SlPolicy for DsdeEntropy {
    fn name(&self) -> &'static str {
        "dsde+entropy"
    }

    fn propose(&self, sig: &SeqSignals) -> usize {
        self.inner.propose(sig)
    }

    fn should_stop(&self, sig: &SeqSignals, j: usize, entropy: f32, _top_p: f32) -> bool {
        if j == 0 {
            return false; // always draft at least one token
        }
        let bound = 1.0 - self.lambda * (entropy.max(0.0) as f64).sqrt();
        bound < self.theta * sig.accept_ewma
    }

    fn wants_calibration(&self) -> bool {
        true
    }

    fn calibration_steps(&self) -> usize {
        self.inner.config().calib_steps
    }

    fn finish_calibration(&self, sig: &mut SeqSignals) {
        sig.calibrated_sl_max = Some(self.inner.calibrated_sl_max(sig));
    }
}

/// Oracle upper bound: told (by the harness) how many tokens will be
/// accepted, it proposes exactly that + 1.  Only usable on the simulator
/// where the bench can peek at the acceptance process; quantifies the
/// remaining headroom of any online predictor.
#[derive(Clone, Debug, Default)]
pub struct OracleHint {
    /// next-round accepted-run hint, set by the harness between rounds
    pub next_accept: std::cell::Cell<usize>,
}

// OracleHint is driven by the single-threaded bench harness.
unsafe impl Sync for OracleHint {}

/// The oracle SL policy driven by an [`OracleHint`] (see its docs).
#[derive(Clone, Debug)]
pub struct OraclePolicy {
    /// Shared hint cell the bench harness writes between rounds.
    pub hint: std::sync::Arc<OracleHint>,
    /// Hard SL ceiling (the verify graph's K).
    pub sl_limit: usize,
}

impl SlPolicy for OraclePolicy {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn propose(&self, _sig: &SeqSignals) -> usize {
        (self.hint.next_accept.get() + 1).clamp(1, self.sl_limit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn signals(klds: &[f32], sl_max: Option<usize>) -> SeqSignals {
        let mut s = SeqSignals::default();
        for &k in klds {
            s.record_step(&[k], &[0.4], 4, 2);
        }
        s.calibrated_sl_max = sl_max;
        s
    }

    #[test]
    fn full_variant_matches_dsde() {
        let cfg = DsdeConfig::default();
        let ab = DsdeAblated::new(cfg.clone(), DsdeVariant::Full);
        let base = DsdeAdapter::new(cfg);
        for klds in [[0.05f32; 30], [0.5; 30], [1.5; 30]] {
            let s = signals(&klds, Some(10));
            assert_eq!(ab.propose(&s), base.propose(&s), "klds {:?}", klds[0]);
        }
    }

    #[test]
    fn sf_only_ignores_history_variance() {
        let ab = DsdeAblated::new(DsdeConfig::default(), DsdeVariant::SfOnly);
        // bursty history but calm last step -> SF-only stays aggressive
        let mut s = SeqSignals::default();
        for k in [0.05f32, 2.0, 0.05, 2.0, 0.05, 2.0, 0.05, 2.0, 0.05, 0.05] {
            s.record_step(&[k], &[0.4], 4, 2);
        }
        s.calibrated_sl_max = Some(10);
        let full = DsdeAblated::new(DsdeConfig::default(), DsdeVariant::Full);
        assert!(ab.propose(&s) >= full.propose(&s));
    }

    #[test]
    fn wvir_only_ignores_kld_level() {
        let ab = DsdeAblated::new(DsdeConfig::default(), DsdeVariant::WvirOnly);
        // constant (stable) but HIGH kld: WVIR-only sees no instability
        let s = signals(&[2.0; 30], Some(10));
        assert_eq!(ab.propose(&s), 10);
        // the full rule collapses to min here
        let full = DsdeAblated::new(DsdeConfig::default(), DsdeVariant::Full);
        assert_eq!(full.propose(&s), 2);
    }

    #[test]
    fn names_are_distinct() {
        let cfg = DsdeConfig::default;
        assert_ne!(
            DsdeAblated::new(cfg(), DsdeVariant::SfOnly).name(),
            DsdeAblated::new(cfg(), DsdeVariant::WvirOnly).name()
        );
    }

    #[test]
    fn entropy_variant_stops_on_high_entropy() {
        let p = DsdeEntropy::new(DsdeConfig::default(), 0.35, 0.6);
        let s = SeqSignals::default();
        assert!(!p.should_stop(&s, 0, 99.0, 0.0), "never stop at j=0");
        assert!(p.should_stop(&s, 1, 9.0, 0.0));
        assert!(!p.should_stop(&s, 1, 0.01, 0.9));
    }

    #[test]
    fn oracle_follows_hint() {
        let hint = std::sync::Arc::new(OracleHint::default());
        let p = OraclePolicy {
            hint: hint.clone(),
            sl_limit: 12,
        };
        let s = SeqSignals::default();
        hint.next_accept.set(5);
        assert_eq!(p.propose(&s), 6);
        hint.next_accept.set(99);
        assert_eq!(p.propose(&s), 12);
    }
}
