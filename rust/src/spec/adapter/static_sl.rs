//! Static speculation-length baseline — what vLLM ships today: one fixed
//! SL for every sequence and every step.  The paper's "Static-opt" is this
//! policy with the per-dataset best k found by profiling (the costly sweep
//! our Fig. 6 bench reproduces).

use super::SlPolicy;
use crate::spec::history::SeqSignals;

/// Fixed-SL policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StaticSl {
    /// The fixed speculation length proposed every round.
    pub k: usize,
}

impl StaticSl {
    /// Construct with the fixed speculation length `k`.
    pub fn new(k: usize) -> StaticSl {
        StaticSl { k }
    }
}

impl SlPolicy for StaticSl {
    fn name(&self) -> &'static str {
        "static"
    }

    fn propose(&self, _sig: &SeqSignals) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_proposes_k() {
        let p = StaticSl::new(6);
        let mut s = SeqSignals::default();
        assert_eq!(p.propose(&s), 6);
        s.record_step(&[9.0; 4], &[3.0; 4], 4, 0); // terrible signals
        assert_eq!(p.propose(&s), 6); // ...static doesn't care
    }

    #[test]
    fn no_early_stop() {
        let p = StaticSl::new(4);
        let s = SeqSignals::default();
        assert!(!p.should_stop(&s, 0, 99.0, 0.0));
        assert!(!p.wants_calibration());
    }
}
