//! SL adapters — the per-sequence, per-iteration speculation-length
//! policies.  [`DsdeAdapter`] is the paper's contribution; [`StaticSl`],
//! [`AdaEdl`] and autoregressive mode (SL = 0 handled by the engine) are the
//! evaluation baselines.
//!
//! # Example: driving the DSDE adapter by hand
//!
//! The engine does this internally; standalone, the loop is: construct the
//! adapter, feed it per-step KLD observations through [`SeqSignals`], and
//! read back the proposed SL.
//!
//! ```
//! use dsde::spec::adapter::{DsdeAdapter, DsdeConfig, SlPolicy};
//! use dsde::spec::history::SeqSignals;
//!
//! let adapter = DsdeAdapter::new(DsdeConfig::default());
//! let mut sig = SeqSignals::default();
//!
//! // fresh sequence: the adapter asks for its calibration draft length
//! assert_eq!(adapter.propose(&sig), 10);
//!
//! // feed verification steps: per-token KLDs + entropies, drafted, accepted
//! for _ in 0..8 {
//!     sig.record_step(&[0.05, 0.04, 0.06], &[0.3, 0.2, 0.25], 3, 3);
//! }
//! sig.calibrated_sl_max = Some(10);
//!
//! // calm, low-KLD history ⇒ an aggressive SL near SL_max; the proposal
//! // always stays inside [sl_min, sl_limit]
//! let sl = adapter.propose(&sig);
//! assert!((2..=10).contains(&sl), "sl = {sl}");
//! ```

pub mod adaedl;
pub mod dsde;
pub mod static_sl;
pub mod variants;

pub use adaedl::{AdaEdl, AdaEdlConfig};
pub use dsde::{DsdeAdapter, DsdeConfig};
pub use static_sl::StaticSl;
pub use variants::{DsdeAblated, DsdeEntropy, DsdeVariant};

use crate::spec::history::SeqSignals;

/// A per-sequence speculation-length policy.
///
/// The engine calls [`SlPolicy::propose`] before each speculative round to
/// get the sequence's requested SL, and may consult
/// [`SlPolicy::should_stop`] after each drafted token (early-stopping
/// policies like AdaEDL).  All policies are **training-free**: the only
/// inputs are the sequence's online signal history.
pub trait SlPolicy: Send {
    /// Stable policy name (metrics/bench/CLI label).
    fn name(&self) -> &'static str;

    /// Requested speculation length for the next round (before SL-cap and
    /// budget clamping).
    fn propose(&self, sig: &SeqSignals) -> usize;

    /// Early-stop check during drafting: called after drafting token `j`
    /// (0-based) with the draft's entropy and top-token probability at that
    /// slot.  Returning true stops this sequence's drafting at j+1 tokens.
    fn should_stop(&self, _sig: &SeqSignals, _j: usize, _entropy: f32, _top_p: f32) -> bool {
        false
    }

    /// Whether the policy wants the engine to run the calibration phase
    /// (paper §3.1.1) for new sequences.
    fn wants_calibration(&self) -> bool {
        false
    }

    /// Number of preliminary speculative steps in the calibration phase.
    fn calibration_steps(&self) -> usize {
        0
    }

    /// Freeze the calibration (e.g. compute Eq. 1's SL_max) once the
    /// calibration phase completes.  Default: no-op.
    fn finish_calibration(&self, _sig: &mut SeqSignals) {}
}

/// Construct a policy from config (used by CLI/bench plumbing).
///
/// ```
/// use dsde::config::SlPolicyKind;
/// use dsde::spec::adapter::{make_policy, SlPolicy};
/// use dsde::spec::history::SeqSignals;
///
/// let policy = make_policy(&SlPolicyKind::Static(6));
/// assert_eq!(policy.name(), "static");
/// assert_eq!(policy.propose(&SeqSignals::default()), 6);
/// ```
pub fn make_policy(kind: &crate::config::SlPolicyKind) -> Box<dyn SlPolicy> {
    use crate::config::SlPolicyKind;
    match kind {
        SlPolicyKind::Static(k) => Box::new(StaticSl::new(*k)),
        SlPolicyKind::Dsde(cfg) => Box::new(DsdeAdapter::new(cfg.clone())),
        SlPolicyKind::AdaEdl(cfg) => Box::new(AdaEdl::new(cfg.clone())),
    }
}
