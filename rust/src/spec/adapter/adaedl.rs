//! AdaEDL baseline (Agrawal et al. 2024): entropy-based early draft
//! stopping.  The draft proposes up to `base` tokens but stops as soon as
//! the entropy-derived lower bound on the acceptance probability,
//! `1 − λ·sqrt(H(q_j))`, falls below a threshold modulated by the
//! historical acceptance rate.  A forward-looking signal — the contrast to
//! DSDE's post-hoc KLD diagnostics the paper leans on in §4.4 (AdaEDL's
//! draft-side confidence goes wrong exactly when draft and target diverge).

use super::SlPolicy;
use crate::spec::history::SeqSignals;

/// AdaEDL configuration (paper evaluates `base = 7`).
#[derive(Clone, Debug, PartialEq)]
pub struct AdaEdlConfig {
    /// Maximum draft length per step (the "base" hyperparameter).
    pub base: usize,
    /// λ — entropy penalty coefficient in the acceptance lower bound.
    pub lambda: f64,
    /// θ — stop threshold scale on the historical acceptance EWMA.
    pub theta: f64,
    /// Minimum SL (never stop before drafting this many).
    pub sl_min: usize,
}

impl Default for AdaEdlConfig {
    fn default() -> Self {
        AdaEdlConfig {
            base: 7,
            lambda: 0.35,
            theta: 0.6,
            sl_min: 1,
        }
    }
}

/// See module docs.
#[derive(Clone, Debug)]
pub struct AdaEdl {
    cfg: AdaEdlConfig,
}

impl AdaEdl {
    /// Construct from config.
    pub fn new(cfg: AdaEdlConfig) -> AdaEdl {
        AdaEdl { cfg }
    }

    /// The policy's configuration.
    pub fn config(&self) -> &AdaEdlConfig {
        &self.cfg
    }

    /// Entropy-based lower bound on the acceptance probability of slot j.
    pub fn acceptance_lower_bound(&self, entropy: f32) -> f64 {
        1.0 - self.cfg.lambda * (entropy.max(0.0) as f64).sqrt()
    }
}

impl SlPolicy for AdaEdl {
    fn name(&self) -> &'static str {
        "adaedl"
    }

    fn propose(&self, _sig: &SeqSignals) -> usize {
        self.cfg.base
    }

    fn should_stop(&self, sig: &SeqSignals, j: usize, entropy: f32, _top_p: f32) -> bool {
        if j + 1 < self.cfg.sl_min {
            return false;
        }
        let bound = self.acceptance_lower_bound(entropy);
        let threshold = self.cfg.theta * sig.accept_ewma;
        bound < threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposes_base() {
        let p = AdaEdl::new(AdaEdlConfig::default());
        assert_eq!(p.propose(&SeqSignals::default()), 7);
    }

    #[test]
    fn low_entropy_keeps_drafting() {
        let p = AdaEdl::new(AdaEdlConfig::default());
        let s = SeqSignals::default();
        assert!(!p.should_stop(&s, 2, 0.01, 0.99));
    }

    #[test]
    fn high_entropy_stops() {
        let p = AdaEdl::new(AdaEdlConfig::default());
        let s = SeqSignals::default(); // accept_ewma starts at 1.0
        // bound = 1 - 0.35*sqrt(9) = -0.05 < 0.6
        assert!(p.should_stop(&s, 2, 9.0, 0.1));
    }

    #[test]
    fn threshold_scales_with_historical_acceptance() {
        let p = AdaEdl::new(AdaEdlConfig::default());
        let mut low_acc = SeqSignals::default();
        for _ in 0..20 {
            low_acc.record_step(&[1.0], &[1.0], 4, 0);
        }
        // with terrible history, the threshold drops -> keeps drafting longer
        let ent = 1.2f32; // bound = 1 - 0.35*1.095 ≈ 0.617
        let fresh = SeqSignals::default();
        assert!(!p.should_stop(&fresh, 2, ent, 0.5) || p.should_stop(&fresh, 2, ent, 0.5));
        // bound 0.617 vs fresh threshold 0.6 -> continue; vs low-acc threshold ~0 -> continue
        assert!(!p.should_stop(&low_acc, 2, ent, 0.5));
        // but at higher entropy fresh stops while low-acc still drafts
        let ent2 = 3.0f32; // bound = 1 - 0.35*1.732 ≈ 0.394
        assert!(p.should_stop(&fresh, 2, ent2, 0.5));
        assert!(!p.should_stop(&low_acc, 2, ent2, 0.5));
    }

    #[test]
    fn respects_sl_min() {
        let p = AdaEdl::new(AdaEdlConfig {
            sl_min: 3,
            ..Default::default()
        });
        let s = SeqSignals::default();
        assert!(!p.should_stop(&s, 0, 99.0, 0.0));
        assert!(!p.should_stop(&s, 1, 99.0, 0.0));
        assert!(p.should_stop(&s, 2, 99.0, 0.0));
    }

    #[test]
    fn lower_bound_monotone_in_entropy() {
        let p = AdaEdl::new(AdaEdlConfig::default());
        assert!(p.acceptance_lower_bound(0.5) > p.acceptance_lower_bound(2.0));
        assert!((p.acceptance_lower_bound(0.0) - 1.0).abs() < 1e-12);
    }
}
