//! Distribution utilities over f32 logits: softmax, log-softmax, KLD,
//! entropy.  The serving hot path gets these fused from the Pallas
//! `kld_stats` kernel inside the verify graph; this host implementation is
//! the oracle for tests, the fallback for the simulator, and the basis of
//! the rejection sampler's residual distribution.

/// In-place numerically-stable softmax.
pub fn softmax(logits: &mut [f32]) {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in logits.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in logits.iter_mut() {
            *x /= sum;
        }
    }
}

/// Softmax with temperature into a fresh Vec. `temp <= 0` produces a
/// one-hot argmax distribution (greedy decoding's limit).
pub fn softmax_t(logits: &[f32], temp: f64) -> Vec<f32> {
    let mut out = vec![0.0f32; logits.len()];
    if temp <= 0.0 {
        let mut bi = 0;
        let mut bv = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            if x > bv {
                bv = x;
                bi = i;
            }
        }
        out[bi] = 1.0;
        return out;
    }
    let t = temp as f32;
    for (o, &x) in out.iter_mut().zip(logits) {
        *o = x / t;
    }
    softmax(&mut out);
    out
}

/// KL(p || q) between two probability vectors (natural log).
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f32 {
    debug_assert_eq!(p.len(), q.len());
    let mut kl = 0.0f64;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            kl += pi as f64 * ((pi as f64).ln() - (qi.max(1e-12) as f64).ln());
        }
    }
    kl as f32
}

/// Shannon entropy of a probability vector (nats).
pub fn entropy(p: &[f32]) -> f32 {
    let mut h = 0.0f64;
    for &pi in p {
        if pi > 0.0 {
            h -= pi as f64 * (pi as f64).ln();
        }
    }
    h as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        softmax(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut x = vec![1000.0f32, 1001.0];
        softmax(&mut x);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_t_zero_is_one_hot() {
        let p = softmax_t(&[0.5, 3.0, -1.0], 0.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_t_high_temp_flattens() {
        let p1 = softmax_t(&[1.0, 2.0], 0.5);
        let p2 = softmax_t(&[1.0, 2.0], 4.0);
        assert!(p2[0] > p1[0], "higher temp is flatter");
    }

    #[test]
    fn kld_zero_for_identical() {
        let p = softmax_t(&[0.3, 1.0, -2.0], 1.0);
        assert!(kl_divergence(&p, &p).abs() < 1e-6);
    }

    #[test]
    fn kld_nonnegative_and_asymmetric() {
        let p = softmax_t(&[2.0, 0.0, 0.0], 1.0);
        let q = softmax_t(&[0.0, 0.0, 2.0], 1.0);
        let ab = kl_divergence(&p, &q);
        let ba = kl_divergence(&q, &p);
        assert!(ab > 0.0);
        assert!((ab - ba).abs() < 1e-6, "symmetric by construction here");
        let r = softmax_t(&[1.0, 0.5, 0.0], 1.0);
        assert!(kl_divergence(&p, &r) >= 0.0);
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let p = vec![0.25f32; 4];
        assert!((entropy(&p) - (4.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn entropy_onehot_is_zero() {
        let p = vec![0.0f32, 1.0, 0.0];
        assert!(entropy(&p).abs() < 1e-9);
    }
}
