//! The real model path: draft/verify rounds over the AOT-compiled PJRT
//! executables, with exact rejection sampling on the true distributions.
//!
//! Faithfulness notes:
//! * Drafting is batch-synchronous (one `draft_step` launch per slot j over
//!   the whole padded batch) — the cost of a round therefore follows
//!   `max_i k_i`, which is precisely the straggler effect the paper's
//!   SL-cap targets (§3.3); it emerges here from the substrate, not from a
//!   model assumption.
//! * Verification is a single ragged batched pass along `K = spec_k` with
//!   per-sequence validity masks and a reserved padding token id (§3.2).
//! * The KLD/entropy signals come fused from the Pallas `kld_stats` kernel
//!   inside the verify graph, measured on unscaled (temperature-1) logits:
//!   they diagnose *model disagreement* irrespective of the sampling
//!   temperature.  Rejection sampling itself uses the temperature-scaled
//!   distributions, so emitted tokens are exactly target-distributed.

use anyhow::Result;

use super::traits::{RoundOutcome, SeqInput, SpecModel, StopFn};
use crate::runtime::artifacts::DraftKind;
use crate::runtime::exec::{GraphKind, PjrtContext};
use crate::spec::kld::{entropy as dist_entropy, softmax_t};
use crate::spec::rejection::{verify_sequence, verify_sequence_greedy};
use crate::util::rng::Rng;

/// PJRT-backed draft/target pair.
pub struct PjrtModel {
    ctx: PjrtContext,
    rng: Rng,
    /// scratch buffers reused across rounds (no hot-loop allocation)
    tok_buf: Vec<i32>,
    len_buf: Vec<i32>,
    att_buf: Vec<i32>,
    dlog_buf: Vec<f32>,
}

impl PjrtModel {
    /// Bring up the PJRT context over an artifact directory with the
    /// chosen draft weights.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, draft: DraftKind, seed: u64) -> Result<PjrtModel> {
        let ctx = PjrtContext::new(artifact_dir, draft)?;
        Ok(PjrtModel {
            ctx,
            rng: Rng::new(seed),
            tok_buf: Vec::new(),
            len_buf: Vec::new(),
            att_buf: Vec::new(),
            dlog_buf: Vec::new(),
        })
    }

    /// Pre-compile graphs for a bucket (avoids first-request latency).
    pub fn warmup(&mut self, batch: usize) -> Result<()> {
        let bucket = self.ctx.bucket_for(batch);
        self.ctx.warmup(bucket)
    }

    /// Cumulative `(PJRT seconds, PJRT calls)` for the perf log.
    pub fn pjrt_stats(&self) -> (f64, u64) {
        (self.ctx.exec_seconds, self.ctx.exec_calls)
    }

    /// Fill the padded token/length buffers for the current batch state.
    /// `extra[i]` holds tokens drafted so far for sequence i this round.
    fn fill_batch(
        &mut self,
        seqs: &[SeqInput<'_>],
        extra: &[Vec<u32>],
        bucket: usize,
    ) {
        let l = self.ctx.max_len();
        let pad = self.ctx.pad_id() as i32;
        self.tok_buf.clear();
        self.tok_buf.resize(bucket * l, pad);
        self.len_buf.clear();
        self.len_buf.resize(bucket, 1);
        for (i, s) in seqs.iter().enumerate() {
            let row = &mut self.tok_buf[i * l..(i + 1) * l];
            for (j, &t) in s.tokens.iter().enumerate() {
                row[j] = t as i32;
            }
            let base = s.tokens.len();
            for (j, &t) in extra[i].iter().enumerate() {
                row[base + j] = t as i32;
            }
            self.len_buf[i] = (base + extra[i].len()) as i32;
        }
    }
}

impl SpecModel for PjrtModel {
    fn max_len(&self) -> usize {
        self.ctx.max_len()
    }

    fn spec_k(&self) -> usize {
        self.ctx.spec_k()
    }

    fn name(&self) -> String {
        "pjrt".to_string()
    }

    fn spec_round(
        &mut self,
        seqs: &[SeqInput<'_>],
        sl: &[usize],
        stop: &StopFn<'_>,
    ) -> Result<RoundOutcome> {
        let b = seqs.len();
        assert_eq!(sl.len(), b);
        let k_graph = self.ctx.spec_k();
        let v = self.ctx.vocab();
        let bucket = self.ctx.bucket_for(b);
        let max_sl = sl.iter().copied().max().unwrap_or(0).min(k_graph);

        // ---- draft phase: batch-synchronous micro-steps ----------------------
        let mut drafted: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut draft_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); b]; // [b][j][V]
        let mut active: Vec<bool> = sl.iter().map(|&k| k > 0).collect();
        for j in 0..max_sl {
            if !active.iter().any(|&a| a) {
                break;
            }
            self.fill_batch(seqs, &drafted, bucket);
            let tok_buf = std::mem::take(&mut self.tok_buf);
            let len_buf = std::mem::take(&mut self.len_buf);
            let out = self.ctx.step(GraphKind::DraftStep, bucket, &tok_buf, &len_buf)?;
            self.tok_buf = tok_buf;
            self.len_buf = len_buf;
            for i in 0..b {
                if !active[i] {
                    continue;
                }
                let logits = out.row(i);
                let dist = softmax_t(logits, seqs[i].temperature);
                let tok = if seqs[i].temperature <= 0.0 {
                    crate::util::rng::argmax(logits) as u32
                } else {
                    crate::spec::rejection::sample_dist(&mut self.rng, &dist)
                };
                // entropy at measurement temperature 1 (signal contract)
                let sig_dist = softmax_t(logits, 1.0);
                let ent = dist_entropy(&sig_dist);
                let top_p = dist.iter().copied().fold(0.0f32, f32::max);
                drafted[i].push(tok);
                draft_logits[i].push(logits.to_vec());
                let reached = drafted[i].len() >= sl[i];
                if reached || stop(i, j, ent, top_p) {
                    active[i] = false;
                }
            }
        }

        // ---- verify phase: one ragged batched pass ---------------------------
        self.fill_batch(seqs, &drafted, bucket); // att lens = ctx + k_i
        self.att_buf.clear();
        self.att_buf.extend_from_slice(&self.len_buf);
        let mut ctx_lens = vec![1i32; bucket];
        for (i, s) in seqs.iter().enumerate() {
            ctx_lens[i] = s.tokens.len() as i32;
        }
        self.dlog_buf.clear();
        self.dlog_buf.resize(bucket * k_graph * v, 0.0);
        for i in 0..b {
            for (j, row) in draft_logits[i].iter().enumerate() {
                let base = (i * k_graph + j) * v;
                self.dlog_buf[base..base + v].copy_from_slice(row);
            }
        }
        let tok_buf = std::mem::take(&mut self.tok_buf);
        let att_buf = std::mem::take(&mut self.att_buf);
        let dlog_buf = std::mem::take(&mut self.dlog_buf);
        let vout = self
            .ctx
            .verify(bucket, &tok_buf, &ctx_lens, &att_buf, &dlog_buf)?;
        self.tok_buf = tok_buf;
        self.att_buf = att_buf;
        self.dlog_buf = dlog_buf;

        // ---- rejection sampling ----------------------------------------------
        let mut out = RoundOutcome::with_capacity(b);
        for i in 0..b {
            let k_i = drafted[i].len();
            let temp = seqs[i].temperature;
            let outcome = if temp <= 0.0 {
                let rows: Vec<&[f32]> = (0..=k_i).map(|j| vout.tlogits_row(i, j)).collect();
                verify_sequence_greedy(&drafted[i], &rows)
            } else {
                let q: Vec<Vec<f32>> = draft_logits[i]
                    .iter()
                    .map(|lg| softmax_t(lg, temp))
                    .collect();
                let p: Vec<Vec<f32>> = (0..=k_i)
                    .map(|j| softmax_t(vout.tlogits_row(i, j), temp))
                    .collect();
                verify_sequence(&mut self.rng, &drafted[i], &q, &p)
            };
            let klds: Vec<f32> = (0..k_i).map(|j| vout.kld_at(i, j)).collect();
            let ents: Vec<f32> = (0..k_i).map(|j| vout.entropy_at(i, j)).collect();
            out.drafted.push(k_i);
            out.accepted.push(outcome.accepted);
            out.new_tokens.push(outcome.tokens);
            out.klds.push(klds);
            out.entropies.push(ents);
        }
        debug_assert!(out.validate(b).is_ok());
        Ok(out)
    }

    fn ar_round(&mut self, seqs: &[SeqInput<'_>]) -> Result<RoundOutcome> {
        let b = seqs.len();
        let bucket = self.ctx.bucket_for(b);
        let empties: Vec<Vec<u32>> = vec![Vec::new(); b];
        self.fill_batch(seqs, &empties, bucket);
        let tok_buf = std::mem::take(&mut self.tok_buf);
        let len_buf = std::mem::take(&mut self.len_buf);
        let step = self
            .ctx
            .step(GraphKind::TargetStep, bucket, &tok_buf, &len_buf)?;
        self.tok_buf = tok_buf;
        self.len_buf = len_buf;
        let mut out = RoundOutcome::with_capacity(b);
        for (i, s) in seqs.iter().enumerate() {
            let logits = step.row(i);
            let tok = if s.temperature <= 0.0 {
                crate::util::rng::argmax(logits) as u32
            } else {
                let dist = softmax_t(logits, s.temperature);
                crate::spec::rejection::sample_dist(&mut self.rng, &dist)
            };
            out.new_tokens.push(vec![tok]);
            out.drafted.push(0);
            out.accepted.push(0);
            out.klds.push(Vec::new());
            out.entropies.push(Vec::new());
        }
        Ok(out)
    }
}
