//! The engine↔model contract.
//!
//! A `SpecModel` executes one *round* for a scheduled batch: either a
//! speculative round (draft k_i tokens per sequence, verify in one ragged
//! batched pass, rejection-sample) or an autoregressive round (one target
//! token each).  Everything above this trait — scheduling, KV accounting,
//! SL adaptation, capping, metrics — is identical between the real PJRT
//! path and the calibrated simulator, which is what makes the benchmark
//! results attributable to the algorithms rather than the substrate.

use anyhow::Result;

/// One scheduled sequence's view for a round.
#[derive(Clone, Debug)]
pub struct SeqInput<'a> {
    /// Stable sequence id (simulator keys its per-sequence processes on it).
    pub id: u64,
    /// Current token buffer: prompt + generated so far.
    pub tokens: &'a [u32],
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
}

/// Result of one round for the whole scheduled batch (parallel arrays over
/// the input order).
#[derive(Clone, Debug, Default)]
pub struct RoundOutcome {
    /// Tokens to append per sequence (accepted prefix + correction/bonus —
    /// always at least 1 token per sequence in a successful round).
    pub new_tokens: Vec<Vec<u32>>,
    /// Draft tokens actually proposed (k_i after any early stopping).
    pub drafted: Vec<usize>,
    /// Draft tokens accepted by verification.
    pub accepted: Vec<usize>,
    /// Per-slot KLD(target ‖ draft) signals for the drafted slots.
    pub klds: Vec<Vec<f32>>,
    /// Per-slot draft entropy for the drafted slots.
    pub entropies: Vec<Vec<f32>>,
    /// Virtual cost of this round in seconds — `Some` on the simulator
    /// path, `None` on the real path (the engine uses wall-clock instead).
    pub sim_cost: Option<f64>,
}

impl RoundOutcome {
    /// Empty outcome with capacity for an `n`-sequence batch.
    pub fn with_capacity(n: usize) -> RoundOutcome {
        RoundOutcome {
            new_tokens: Vec::with_capacity(n),
            drafted: Vec::with_capacity(n),
            accepted: Vec::with_capacity(n),
            klds: Vec::with_capacity(n),
            entropies: Vec::with_capacity(n),
            sim_cost: None,
        }
    }

    /// Internal consistency checks (used by engine debug assertions and
    /// property tests).
    pub fn validate(&self, batch: usize) -> Result<(), String> {
        if self.new_tokens.len() != batch
            || self.drafted.len() != batch
            || self.accepted.len() != batch
            || self.klds.len() != batch
            || self.entropies.len() != batch
        {
            return Err("outcome arity mismatch".to_string());
        }
        for i in 0..batch {
            if self.accepted[i] > self.drafted[i] {
                return Err(format!(
                    "seq {i}: accepted {} > drafted {}",
                    self.accepted[i], self.drafted[i]
                ));
            }
            // emitted tokens = accepted + 1 (correction or bonus)
            if self.new_tokens[i].len() != self.accepted[i] + 1 {
                return Err(format!(
                    "seq {i}: {} tokens != accepted {} + 1",
                    self.new_tokens[i].len(),
                    self.accepted[i]
                ));
            }
            if self.klds[i].len() != self.drafted[i]
                || self.entropies[i].len() != self.drafted[i]
            {
                return Err(format!("seq {i}: signal length != drafted"));
            }
        }
        Ok(())
    }
}

/// Early-stop callback: `(batch_index, slot_j, draft_entropy, top_prob)`
/// → stop drafting this sequence after slot j.
pub type StopFn<'a> = dyn Fn(usize, usize, f32, f32) -> bool + 'a;

/// The model behind the engine.  `Send` so the engine (and the model in
/// it) can move into a dedicated serving thread.
pub trait SpecModel: Send {
    /// Padded context capacity.
    fn max_len(&self) -> usize;

    /// Hard ceiling on per-round speculation length.
    fn spec_k(&self) -> usize;

    /// Human-readable tag for logs/metrics.
    fn name(&self) -> String;

    /// One speculative round. `sl[i] >= 1` is the requested draft length for
    /// `seqs[i]`; implementations may stop earlier when `stop` returns true.
    fn spec_round(
        &mut self,
        seqs: &[SeqInput<'_>],
        sl: &[usize],
        stop: &StopFn<'_>,
    ) -> Result<RoundOutcome>;

    /// One autoregressive round (baseline): exactly one target token per
    /// sequence; outcome has `drafted = accepted = 0`.
    fn ar_round(&mut self, seqs: &[SeqInput<'_>]) -> Result<RoundOutcome>;

    /// Drop any per-sequence state (called when a sequence retires).
    fn release(&mut self, _id: u64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_consistent_outcome() {
        let o = RoundOutcome {
            new_tokens: vec![vec![1, 2, 3]],
            drafted: vec![4],
            accepted: vec![2],
            klds: vec![vec![0.1; 4]],
            entropies: vec![vec![0.2; 4]],
            sim_cost: None,
        };
        assert!(o.validate(1).is_ok());
    }

    #[test]
    fn validate_rejects_bad_token_count() {
        let o = RoundOutcome {
            new_tokens: vec![vec![1]],
            drafted: vec![4],
            accepted: vec![2],
            klds: vec![vec![0.0; 4]],
            entropies: vec![vec![0.0; 4]],
            sim_cost: None,
        };
        assert!(o.validate(1).is_err());
    }

    #[test]
    fn validate_rejects_accept_over_draft() {
        let o = RoundOutcome {
            new_tokens: vec![vec![1, 2, 3, 4, 5, 6]],
            drafted: vec![4],
            accepted: vec![5],
            klds: vec![vec![0.0; 4]],
            entropies: vec![vec![0.0; 4]],
            sim_cost: None,
        };
        assert!(o.validate(1).is_err());
    }

    #[test]
    fn validate_rejects_arity_mismatch() {
        let o = RoundOutcome::with_capacity(0);
        assert!(o.validate(2).is_err());
    }
}
