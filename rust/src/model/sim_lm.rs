//! Simulated model path: drives the engine with the acceptance-regime
//! process + latency cost model instead of real forwards.  Used by all
//! paper-scale benchmark sweeps; the engine code above the
//! [`SpecModel`] trait is byte-identical to the PJRT path.

use std::collections::HashMap;

use anyhow::Result;

use super::traits::{RoundOutcome, SeqInput, SpecModel, StopFn};
use crate::sim::cost::CostModel;
use crate::sim::regime::{DatasetProfile, RegimeProcess};
use crate::util::rng::Rng;

/// Which draft/target pair the simulator emulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimPairKind {
    /// LLaMA-3.1-70B / LLaMA-3.2-1B — the paper's main (high-acceptance) pair.
    LlamaLike,
    /// Gemma-27B / Gemma-2B — the §4.4 high-divergence low-acceptance pair.
    GemmaLike,
}

impl SimPairKind {
    /// Acceptance scaling applied to the dataset profile's alphas.
    pub fn alpha_scale(self) -> f64 {
        match self {
            SimPairKind::LlamaLike => 1.0,
            // Gemma pair: k_opt collapses to ~2 on most datasets (§4.4)
            SimPairKind::GemmaLike => 0.62,
        }
    }

    /// Stable pair tag for logs/metrics.  (Cost note: the Gemma target is
    /// cheaper per step than the 70B LLaMA, but Table 4's ratios are
    /// normalized, so both pairs share one cost model and acceptance
    /// drives the divergence.)
    pub fn name(self) -> &'static str {
        match self {
            SimPairKind::LlamaLike => "llama70b-1b",
            SimPairKind::GemmaLike => "gemma27b-2b",
        }
    }
}

/// Per-sequence RNG streams: acceptance coin-flips and token content are
/// drawn from streams keyed by (model seed, sequence id) — NOT from a
/// model-global stream — so a request's output tokens are a pure function
/// of its id and the seed.  That makes generation *placement-independent*:
/// batch composition, routing policy, and work stealing can change round
/// boundaries (and therefore latency), but never the emitted token
/// sequence, because the applied tokens are always a prefix of the
/// sequence's own token stream.
struct SeqRngs {
    accept: Rng,
    token: Rng,
}

/// Simulated draft/target pair over a dataset profile.
pub struct SimModel {
    profile: DatasetProfile,
    pair: SimPairKind,
    cost: CostModel,
    procs: HashMap<u64, RegimeProcess>,
    rngs: HashMap<u64, SeqRngs>,
    max_len: usize,
    spec_k: usize,
    seed: u64,
    /// accumulated virtual model time (for reporting)
    pub virtual_seconds: f64,
}

impl SimModel {
    /// Construct over a dataset profile (the pair's acceptance scaling is
    /// applied here) with the paper-calibrated A100 cost model.
    pub fn new(pair: SimPairKind, profile: DatasetProfile, seed: u64) -> SimModel {
        let profile = profile.with_divergence(pair.alpha_scale());
        SimModel {
            profile,
            pair,
            cost: CostModel::paper_a100(),
            procs: HashMap::new(),
            rngs: HashMap::new(),
            max_len: 4096,
            spec_k: 12,
            seed,
            virtual_seconds: 0.0,
        }
    }

    /// Builder-style latency cost-model override.
    pub fn with_cost(mut self, cost: CostModel) -> SimModel {
        self.cost = cost;
        self
    }

    /// Builder-style context-capacity override.
    pub fn with_max_len(mut self, max_len: usize) -> SimModel {
        self.max_len = max_len;
        self
    }

    /// Builder-style speculation-length ceiling override.
    pub fn with_spec_k(mut self, k: usize) -> SimModel {
        self.spec_k = k;
        self
    }

    /// The (pair-scaled) dataset profile this model simulates.
    pub fn profile(&self) -> &DatasetProfile {
        &self.profile
    }

    fn proc_for(&mut self, id: u64) -> &mut RegimeProcess {
        let profile = self.profile.clone();
        let seed = self.seed;
        self.procs
            .entry(id)
            .or_insert_with(|| RegimeProcess::new(profile, seed ^ id.wrapping_mul(0x9E37)))
    }

    fn rngs_for(&mut self, id: u64) -> &mut SeqRngs {
        let seed = self.seed;
        self.rngs.entry(id).or_insert_with(|| SeqRngs {
            accept: Rng::new(seed ^ id.wrapping_mul(0xC2B2_AE3D_27D4_EB4F) ^ 0xACC),
            token: Rng::new(seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x70C),
        })
    }

    /// Drop per-sequence state for finished requests (bounded memory).
    pub fn forget(&mut self, id: u64) {
        self.procs.remove(&id);
        self.rngs.remove(&id);
    }

    fn gen_token(rng: &mut Rng) -> u32 {
        // printable ASCII filler — content is irrelevant to the simulator
        32 + (rng.range(0, 95) as u32)
    }
}

impl SpecModel for SimModel {
    fn max_len(&self) -> usize {
        self.max_len
    }

    fn spec_k(&self) -> usize {
        self.spec_k
    }

    fn name(&self) -> String {
        format!("sim:{}:{}", self.pair.name(), self.profile.name)
    }

    fn spec_round(
        &mut self,
        seqs: &[SeqInput<'_>],
        sl: &[usize],
        stop: &StopFn<'_>,
    ) -> Result<RoundOutcome> {
        let b = seqs.len();
        let mut out = RoundOutcome::with_capacity(b);
        let mut max_drafted = 0usize;
        for (i, s) in seqs.iter().enumerate() {
            let k_req = sl[i].min(self.spec_k);
            let temperature = s.temperature;
            let id = s.id;
            self.proc_for(id).step_regime();
            // draft k tokens (with early-stop), drawing signals per token
            let mut klds = Vec::with_capacity(k_req);
            let mut ents = Vec::with_capacity(k_req);
            let mut accept_ps = Vec::with_capacity(k_req);
            for j in 0..k_req {
                let draw = self.proc_for(id).draw_token(temperature);
                klds.push(draw.kld);
                ents.push(draw.entropy);
                accept_ps.push(draw.accept_p);
                if stop(i, j, draw.entropy, draw.accept_p as f32) {
                    break;
                }
            }
            let k = accept_ps.len();
            max_drafted = max_drafted.max(k);
            // sequential acceptance + token content from the sequence's own
            // RNG streams (see [`SeqRngs`]): placement-independent output
            let rngs = self.rngs_for(id);
            let mut accepted = 0usize;
            for &a in &accept_ps {
                if rngs.accept.chance(a) {
                    accepted += 1;
                } else {
                    break;
                }
            }
            let mut toks = Vec::with_capacity(accepted + 1);
            for _ in 0..=accepted {
                toks.push(Self::gen_token(&mut rngs.token));
            }
            out.new_tokens.push(toks);
            out.drafted.push(k);
            out.accepted.push(accepted);
            // post-hoc signals exist only for the verified (drafted) slots
            klds.truncate(k);
            ents.truncate(k);
            out.klds.push(klds);
            out.entropies.push(ents);
        }
        let cost = self.cost.spec_round(b, max_drafted);
        self.virtual_seconds += cost;
        out.sim_cost = Some(cost);
        debug_assert!(out.validate(b).is_ok());
        Ok(out)
    }

    fn ar_round(&mut self, seqs: &[SeqInput<'_>]) -> Result<RoundOutcome> {
        let b = seqs.len();
        let mut out = RoundOutcome::with_capacity(b);
        for s in seqs {
            self.proc_for(s.id).step_regime();
            let tok = Self::gen_token(&mut self.rngs_for(s.id).token);
            out.new_tokens.push(vec![tok]);
            out.drafted.push(0);
            out.accepted.push(0);
            out.klds.push(Vec::new());
            out.entropies.push(Vec::new());
        }
        let cost = self.cost.ar_round(b);
        self.virtual_seconds += cost;
        out.sim_cost = Some(cost);
        Ok(out)
    }

    fn release(&mut self, id: u64) {
        self.forget(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_inputs(n: usize) -> Vec<(u64, Vec<u32>)> {
        (0..n).map(|i| (i as u64, vec![65u32; 10])).collect()
    }

    fn views(store: &[(u64, Vec<u32>)], temp: f64) -> Vec<SeqInput<'_>> {
        store
            .iter()
            .map(|(id, t)| SeqInput {
                id: *id,
                tokens: t,
                temperature: temp,
            })
            .collect()
    }

    #[test]
    fn round_outcome_is_valid() {
        let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 1);
        let store = mk_inputs(4);
        let seqs = views(&store, 0.0);
        let out = m.spec_round(&seqs, &[4, 6, 2, 8], &|_, _, _, _| false).unwrap();
        assert!(out.validate(4).is_ok());
        assert!(out.sim_cost.unwrap() > 0.0);
    }

    #[test]
    fn acceptance_rate_reflects_pair() {
        let trials = 300;
        let run = |pair: SimPairKind| -> f64 {
            let mut m = SimModel::new(pair, DatasetProfile::cnndm(), 2);
            let store = mk_inputs(1);
            let mut drafted = 0usize;
            let mut accepted = 0usize;
            for _ in 0..trials {
                let seqs = views(&store, 0.0);
                let out = m.spec_round(&seqs, &[6], &|_, _, _, _| false).unwrap();
                drafted += out.drafted[0];
                accepted += out.accepted[0];
            }
            accepted as f64 / drafted as f64
        };
        // note: the sequential accept-until-first-reject scheme makes the
        // drafted-token acceptance *rate* lower than the per-token prob
        let a_llama = run(SimPairKind::LlamaLike);
        let a_gemma = run(SimPairKind::GemmaLike);
        assert!(a_llama > 0.2, "llama-like acceptance {a_llama}");
        assert!(a_gemma < a_llama - 0.08, "gemma {a_gemma} vs llama {a_llama}");
    }

    #[test]
    fn early_stop_limits_draft() {
        let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 3);
        let store = mk_inputs(1);
        let seqs = views(&store, 0.0);
        let out = m.spec_round(&seqs, &[10], &|_, j, _, _| j >= 2).unwrap();
        assert_eq!(out.drafted[0], 3); // stopped after slot index 2
    }

    #[test]
    fn ar_round_emits_one_token_each() {
        let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::nq(), 4);
        let store = mk_inputs(3);
        let seqs = views(&store, 1.0);
        let out = m.ar_round(&seqs).unwrap();
        for t in &out.new_tokens {
            assert_eq!(t.len(), 1);
        }
        assert!(out.sim_cost.unwrap() > 0.0);
    }

    #[test]
    fn cost_follows_max_k_straggler() {
        let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 5);
        let store = mk_inputs(8);
        let seqs = views(&store, 0.0);
        let uniform = m.spec_round(&seqs, &[2; 8], &|_, _, _, _| false).unwrap();
        let seqs = views(&store, 0.0);
        let ragged = m
            .spec_round(&seqs, &[2, 2, 2, 2, 2, 2, 2, 12], &|_, _, _, _| false)
            .unwrap();
        assert!(
            ragged.sim_cost.unwrap() > uniform.sim_cost.unwrap(),
            "one straggler must lengthen the round"
        );
    }

    #[test]
    fn forget_clears_state() {
        let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 6);
        let store = mk_inputs(1);
        let seqs = views(&store, 0.0);
        m.spec_round(&seqs, &[2], &|_, _, _, _| false).unwrap();
        assert_eq!(m.procs.len(), 1);
        m.forget(0);
        assert!(m.procs.is_empty());
    }

    #[test]
    fn token_content_is_placement_independent() {
        // the emitted token stream for a sequence id is a pure function of
        // (model seed, id): different SL schedules — i.e. different batch
        // compositions / round partitions, as different placements produce —
        // must yield prefix-consistent token streams
        let collect = |k: usize, rounds: usize| -> Vec<u32> {
            let mut m =
                SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 9);
            let store = mk_inputs(1);
            let mut toks = Vec::new();
            for _ in 0..rounds {
                let seqs = views(&store, 0.0);
                let out = m.spec_round(&seqs, &[k], &|_, _, _, _| false).unwrap();
                toks.extend_from_slice(&out.new_tokens[0]);
            }
            toks
        };
        let a = collect(2, 12);
        let b = collect(8, 12);
        let n = a.len().min(b.len());
        assert!(n > 8, "streams long enough to compare");
        assert_eq!(a[..n], b[..n], "token streams must be prefix-consistent");
        // and a fresh model instance (another replica, same seed) agrees
        assert_eq!(collect(2, 12), collect(2, 12));
    }

    #[test]
    fn deterministic_for_seed() {
        let run = || {
            let mut m = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::gsm8k(), 9);
            let store = mk_inputs(2);
            let seqs = views(&store, 0.0);
            let o = m.spec_round(&seqs, &[5, 5], &|_, _, _, _| false).unwrap();
            (o.accepted.clone(), o.new_tokens.clone())
        };
        assert_eq!(run(), run());
    }
}
