//! Byte-level vocabulary (V = 256): text ↔ token conversion + the reserved
//! padding id (paper §3.2: "a reserved padding token ID prevents invalid
//! token identifiers from propagating when SL_i decreases").

/// Reserved padding token (byte 0 never occurs in the ASCII corpus).
pub const PAD_ID: u32 = 0;

/// Vocabulary size.
pub const VOCAB: usize = 256;

/// Encode text as byte tokens.
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

/// Decode tokens back to text (lossy for non-UTF8 byte sequences).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t != PAD_ID)
        .map(|&t| (t & 0xFF) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "def compute(x):\n    return x + 1\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn pad_tokens_dropped_on_decode() {
        let mut toks = encode("ab");
        toks.push(PAD_ID);
        toks.insert(0, PAD_ID);
        assert_eq!(decode(&toks), "ab");
    }

    #[test]
    fn tokens_are_bytes() {
        let toks = encode("A");
        assert_eq!(toks, vec![65]);
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB));
    }
}
