//! Model abstraction: the engine speaks [`traits::SpecModel`]; two
//! implementations exist —
//! * [`pjrt_lm::PjrtModel`] — the real path: AOT-compiled HLO graphs
//!   executed via PJRT (draft steps, batched ragged verify, exact
//!   rejection sampling on real distributions);
//! * [`sim_lm::SimModel`] — the calibrated discrete-event path used by the
//!   paper-scale benchmark sweeps (acceptance-regime process + cost model).

pub mod pjrt_lm;
pub mod sim_lm;
pub mod traits;
pub mod vocab;
