//! Minimal JSON parser/serializer (no `serde` in the offline vendor set).
//!
//! Covers the full JSON grammar we produce and consume: the artifact
//! manifest, engine/bench configs, results dumps, and the HTTP API bodies.
//! Numbers are f64 (adequate for all our payloads); strings support the
//! standard escapes incl. \uXXXX BMP escapes.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys ⇒ deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------------
    /// An empty object (chain with [`Json::set`]).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; a no-op on non-object values.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // ---- accessors -----------------------------------------------------------
    /// Object field lookup; None on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["models", "target", "n_params"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- parsing -------------------------------------------------------------
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

// ---- From conversions --------------------------------------------------------

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse failure, with the byte offset it occurred at.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy UTF-8 continuation bytes verbatim
                    let start = self.i - 1;
                    let width = utf8_width(c);
                    self.i = start + width;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---- serialization -------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Bool(false))
        );
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null],"nested":{"k":"v \"q\""},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn builder_and_display() {
        let j = Json::obj()
            .set("name", "dsde")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", vec![1.0, 2.0]);
        let s = j.to_string();
        assert!(s.contains("\"name\":\"dsde\""));
        assert!(s.contains("\"xs\":[1,2]"));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn path_access_missing_is_none() {
        let j = Json::parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(j.at(&["a", "z"]).is_none());
        assert_eq!(j.at(&["a", "b"]).unwrap().as_usize(), Some(1));
    }
}
