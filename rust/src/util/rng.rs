//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256++ — fast, 256-bit state, passes BigCrush — seeded
//! via SplitMix64 so any u64 seed yields a well-mixed state.  Every
//! stochastic component in the stack (sampling, simulators, workloads,
//! property tests) draws from this, so runs are reproducible end-to-end.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used to expand a single u64 seed into PRNG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a u64 seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per sequence / per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        let span = (hi - lo) as u64;
        // Lemire's debiased multiply-shift.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; perf is irrelevant at our call rates).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate λ (mean 1/λ).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (with Johnk boost for k<1).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a, 1.0);
        let y = self.gamma(b, 1.0);
        x / (x + y)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample a token index from f32 logits at the given temperature.
    /// `temperature == 0` is greedy argmax.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f64) -> usize {
        if temperature <= 0.0 {
            return argmax(logits);
        }
        // numerically stable softmax sample via Gumbel-max
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            let g = -(-(self.f64().max(1e-300)).ln()).ln();
            let v = l as f64 / temperature + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-like rank sample over n items with exponent s (cheap inverse-CDF
    /// over precomputable weights is avoided — n is small in our workloads).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let weights: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
        self.categorical(&weights)
    }
}

/// Argmax over f32 slice (ties -> first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.range(3, 13);
            assert!((3..13).contains(&x));
            seen[x - 3] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn gamma_mean_variance() {
        let mut r = Rng::new(19);
        let (k, th) = (3.0, 2.0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, th)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - k * th).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn beta_in_unit_and_mean() {
        let mut r = Rng::new(23);
        let n = 30_000;
        let xs: Vec<f64> = (0..n).map(|_| r.beta(2.0, 6.0)).collect();
        assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn categorical_proportions() {
        let mut r = Rng::new(29);
        let w = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut r = Rng::new(31);
        let logits = [0.1f32, 5.0, -2.0, 4.9];
        for _ in 0..10 {
            assert_eq!(r.sample_logits(&logits, 0.0), 1);
        }
    }

    #[test]
    fn gumbel_sampling_matches_softmax() {
        let mut r = Rng::new(37);
        let logits = [0.0f32, (2.0f32).ln()]; // probs 1/3, 2/3
        let n = 60_000;
        let ones = (0..n).filter(|_| r.sample_logits(&logits, 1.0) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(41);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(43);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
