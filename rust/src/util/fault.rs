//! Deterministic fault injection for the serving stack's chaos tests.
//!
//! A [`FaultPlan`] is a *schedule* of failure events — replica kills,
//! replica stalls, journal-fsync drops, connection slowdowns — expressed
//! in milliseconds relative to the moment the plan is armed (router
//! construction).  Plans are plain data: they can be written by hand,
//! parsed from a compact CLI spec (`--fault "kill:1@200;slow-conn:5"`),
//! or generated deterministically from a seed ([`FaultPlan::seeded`]) so
//! a chaos soak is exactly reproducible from one u64.
//!
//! [`FaultPlan::arm`] converts the schedule into an [`ArmedFaults`]
//! handle: cheaply cloneable, internally atomic, queried from the hot
//! paths it sabotages (replica loops, the journal's sync point, the HTTP
//! dispatch path).  Kill and stall events are one-shot — each fires at
//! most once; sync-drop is level-triggered from its start time onward;
//! `slow-conn` applies to every request for the process lifetime.
//!
//! This module sits in `util` (not `server`) so the serving
//! configuration layer can carry a plan without a dependency cycle.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// One scheduled fault.  Times are milliseconds since the plan is armed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// Panic the given replica's engine thread at `at_ms` (one-shot).
    KillReplica {
        /// Replica index to kill.
        replica: usize,
        /// Milliseconds after arming at which the kill fires.
        at_ms: u64,
    },
    /// Wedge the given replica's engine thread (a hard sleep inside its
    /// loop, heartbeat frozen) for `for_ms` starting at `at_ms`
    /// (one-shot).
    StallReplica {
        /// Replica index to stall.
        replica: usize,
        /// Milliseconds after arming at which the stall begins.
        at_ms: u64,
        /// Stall duration in milliseconds.
        for_ms: u64,
    },
    /// From `at_ms` onward, the journal skips its fsync (writes still
    /// happen; durability is sacrificed — `journal_lag` keeps growing).
    DropJournalSync {
        /// Milliseconds after arming at which syncs start being dropped.
        at_ms: u64,
    },
    /// Delay every HTTP dispatch by `delay_ms` (level-triggered, always
    /// active) — a crude slow-client / slow-handler simulator.
    SlowConn {
        /// Per-request added latency in milliseconds.
        delay_ms: u64,
    },
}

/// A deterministic schedule of [`FaultEvent`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scheduled events, in no particular order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan (injects nothing).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse the compact CLI spec: `;`-separated events, each one of
    ///
    /// * `kill:R@MS` — kill replica `R` at `MS` ms
    /// * `stall:R@MS+DUR` — stall replica `R` at `MS` ms for `DUR` ms
    /// * `drop-sync@MS` — drop journal fsyncs from `MS` ms onward
    /// * `slow-conn:MS` — delay every HTTP dispatch by `MS` ms
    /// * `seed:S` — expand to [`FaultPlan::seeded`]`(S, replicas, 10_000)`
    ///
    /// `replicas` bounds replica indices (and feeds `seed:` expansion).
    pub fn parse(spec: &str, replicas: usize) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(seed) = part.strip_prefix("seed:") {
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("bad fault seed in {part:?}"))?;
                events.extend(FaultPlan::seeded(seed, replicas, 10_000).events);
            } else if let Some(rest) = part.strip_prefix("kill:") {
                let (replica, at_ms) = parse_at(rest, part)?;
                check_replica(replica, replicas, part)?;
                events.push(FaultEvent::KillReplica { replica, at_ms });
            } else if let Some(rest) = part.strip_prefix("stall:") {
                let (head, for_ms) = rest
                    .split_once('+')
                    .ok_or_else(|| format!("stall needs `+DUR` in {part:?}"))?;
                let (replica, at_ms) = parse_at(head, part)?;
                let for_ms: u64 = for_ms
                    .parse()
                    .map_err(|_| format!("bad stall duration in {part:?}"))?;
                check_replica(replica, replicas, part)?;
                events.push(FaultEvent::StallReplica {
                    replica,
                    at_ms,
                    for_ms,
                });
            } else if let Some(at) = part.strip_prefix("drop-sync@") {
                let at_ms: u64 = at
                    .parse()
                    .map_err(|_| format!("bad drop-sync time in {part:?}"))?;
                events.push(FaultEvent::DropJournalSync { at_ms });
            } else if let Some(ms) = part.strip_prefix("slow-conn:") {
                let delay_ms: u64 = ms
                    .parse()
                    .map_err(|_| format!("bad slow-conn delay in {part:?}"))?;
                events.push(FaultEvent::SlowConn { delay_ms });
            } else {
                return Err(format!("unknown fault event {part:?}"));
            }
        }
        Ok(FaultPlan { events })
    }

    /// Render the plan back into the CLI spec format accepted by
    /// [`FaultPlan::parse`] (round-trips exactly for explicit plans).
    pub fn to_spec(&self) -> String {
        self.events
            .iter()
            .map(|e| match e {
                FaultEvent::KillReplica { replica, at_ms } => {
                    format!("kill:{replica}@{at_ms}")
                }
                FaultEvent::StallReplica {
                    replica,
                    at_ms,
                    for_ms,
                } => format!("stall:{replica}@{at_ms}+{for_ms}"),
                FaultEvent::DropJournalSync { at_ms } => format!("drop-sync@{at_ms}"),
                FaultEvent::SlowConn { delay_ms } => format!("slow-conn:{delay_ms}"),
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Generate a reproducible chaos schedule for a fleet of `replicas`
    /// over roughly `horizon_ms` of serving: one to three kill/stall
    /// events on random replicas at random times in the first half of the
    /// horizon.  At least one replica is always spared so survivors exist
    /// to adopt the dead replica's work.  The same `(seed, replicas,
    /// horizon_ms)` always yields the same plan.
    pub fn seeded(seed: u64, replicas: usize, horizon_ms: u64) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        if replicas >= 2 {
            let n = 1 + rng.range(0, replicas.min(3));
            // never fault every replica: keep one survivor
            let spared = rng.range(0, replicas);
            let window = (horizon_ms / 2).max(20);
            for _ in 0..n {
                let mut replica = rng.range(0, replicas);
                if replica == spared {
                    replica = (replica + 1) % replicas;
                }
                let at_ms = 10 + rng.next_u64() % window;
                if rng.chance(0.5) {
                    events.push(FaultEvent::KillReplica { replica, at_ms });
                } else {
                    events.push(FaultEvent::StallReplica {
                        replica,
                        at_ms,
                        for_ms: horizon_ms.max(100),
                    });
                }
            }
        }
        FaultPlan { events }
    }

    /// Arm the plan: start its clock and build the shared handle the
    /// serving stack queries.
    pub fn arm(&self) -> ArmedFaults {
        ArmedFaults {
            inner: Arc::new(ArmedInner {
                plan: self.clone(),
                fired: (0..self.events.len()).map(|_| AtomicBool::new(false)).collect(),
                epoch: Instant::now(),
            }),
        }
    }
}

fn parse_at(s: &str, part: &str) -> Result<(usize, u64), String> {
    let (r, at) = s
        .split_once('@')
        .ok_or_else(|| format!("expected `R@MS` in {part:?}"))?;
    let replica = r
        .parse()
        .map_err(|_| format!("bad replica index in {part:?}"))?;
    let at_ms = at.parse().map_err(|_| format!("bad time in {part:?}"))?;
    Ok((replica, at_ms))
}

fn check_replica(replica: usize, replicas: usize, part: &str) -> Result<(), String> {
    if replicas > 0 && replica >= replicas {
        return Err(format!(
            "replica {replica} out of range (fleet has {replicas}) in {part:?}"
        ));
    }
    Ok(())
}

struct ArmedInner {
    plan: FaultPlan,
    /// One-shot latch per event (kill/stall fire at most once).
    fired: Vec<AtomicBool>,
    epoch: Instant,
}

/// An armed [`FaultPlan`]: the live handle the serving stack polls.
/// Cloning is cheap (an `Arc` bump); all clones share the one-shot
/// latches and the arm-time epoch.
#[derive(Clone)]
pub struct ArmedFaults {
    inner: Arc<ArmedInner>,
}

impl ArmedFaults {
    fn now_ms(&self) -> u64 {
        self.inner.epoch.elapsed().as_millis() as u64
    }

    /// Whether a `KillReplica` for `replica` is due now.  One-shot: the
    /// first query at-or-after the scheduled time returns true, every
    /// later query false.
    pub fn kill_due(&self, replica: usize) -> bool {
        let now = self.now_ms();
        for (i, e) in self.inner.plan.events.iter().enumerate() {
            if let FaultEvent::KillReplica { replica: r, at_ms } = e {
                if *r == replica && now >= *at_ms && !self.inner.fired[i].swap(true, Ordering::SeqCst)
                {
                    return true;
                }
            }
        }
        false
    }

    /// Whether a `StallReplica` for `replica` is due now; returns the
    /// stall duration.  One-shot like [`ArmedFaults::kill_due`].
    pub fn stall_due(&self, replica: usize) -> Option<Duration> {
        let now = self.now_ms();
        for (i, e) in self.inner.plan.events.iter().enumerate() {
            if let FaultEvent::StallReplica {
                replica: r,
                at_ms,
                for_ms,
            } = e
            {
                if *r == replica
                    && now >= *at_ms
                    && !self.inner.fired[i].swap(true, Ordering::SeqCst)
                {
                    return Some(Duration::from_millis(*for_ms));
                }
            }
        }
        None
    }

    /// Whether journal fsyncs should currently be dropped
    /// (level-triggered: true from the earliest `DropJournalSync.at_ms`
    /// onward).
    pub fn journal_sync_dropped(&self) -> bool {
        let now = self.now_ms();
        self.inner.plan.events.iter().any(|e| {
            matches!(e, FaultEvent::DropJournalSync { at_ms } if now >= *at_ms)
        })
    }

    /// The per-request dispatch delay, if a `SlowConn` event is present.
    pub fn conn_delay(&self) -> Option<Duration> {
        self.inner.plan.events.iter().find_map(|e| match e {
            FaultEvent::SlowConn { delay_ms } => Some(Duration::from_millis(*delay_ms)),
            _ => None,
        })
    }
}

impl std::fmt::Debug for ArmedFaults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ArmedFaults({:?})", self.inner.plan.to_spec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_explicit_events() {
        let spec = "kill:1@200;stall:0@50+300;drop-sync@10;slow-conn:5";
        let plan = FaultPlan::parse(spec, 4).unwrap();
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.to_spec(), spec);
        assert_eq!(FaultPlan::parse(&plan.to_spec(), 4).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_garbage_and_out_of_range() {
        assert!(FaultPlan::parse("explode:1@2", 2).is_err());
        assert!(FaultPlan::parse("kill:1", 2).is_err());
        assert!(FaultPlan::parse("kill:7@10", 2).is_err());
        assert!(FaultPlan::parse("stall:0@10", 2).is_err(), "missing +DUR");
        assert!(FaultPlan::parse("slow-conn:x", 2).is_err());
    }

    #[test]
    fn seeded_is_deterministic_and_spares_a_replica() {
        for seed in 0..50u64 {
            let a = FaultPlan::seeded(seed, 3, 1000);
            let b = FaultPlan::seeded(seed, 3, 1000);
            assert_eq!(a, b, "same seed, same plan");
            let faulted: std::collections::HashSet<usize> = a
                .events
                .iter()
                .filter_map(|e| match e {
                    FaultEvent::KillReplica { replica, .. } => Some(*replica),
                    FaultEvent::StallReplica { replica, .. } => Some(*replica),
                    _ => None,
                })
                .collect();
            assert!(faulted.len() < 3, "seed {seed} faulted every replica");
        }
    }

    #[test]
    fn seeded_single_replica_is_empty() {
        assert!(FaultPlan::seeded(7, 1, 1000).events.is_empty());
    }

    #[test]
    fn kill_and_stall_fire_once_at_their_time() {
        let plan = FaultPlan::parse("kill:0@0;stall:1@0+50", 2).unwrap();
        let armed = plan.arm();
        assert!(!armed.kill_due(1), "wrong replica never fires");
        assert!(armed.kill_due(0));
        assert!(!armed.kill_due(0), "one-shot");
        assert_eq!(armed.stall_due(1), Some(Duration::from_millis(50)));
        assert_eq!(armed.stall_due(1), None, "one-shot");
        assert_eq!(armed.stall_due(0), None);
    }

    #[test]
    fn future_events_do_not_fire_early() {
        let plan = FaultPlan::parse("kill:0@60000", 1).unwrap();
        let armed = plan.arm();
        assert!(!armed.kill_due(0), "a minute out must not fire at arm time");
    }

    #[test]
    fn sync_drop_is_level_triggered() {
        let armed = FaultPlan::parse("drop-sync@0", 1).unwrap().arm();
        assert!(armed.journal_sync_dropped());
        assert!(armed.journal_sync_dropped(), "not one-shot");
        let clean = FaultPlan::none().arm();
        assert!(!clean.journal_sync_dropped());
    }

    #[test]
    fn conn_delay_reports_slow_conn() {
        let armed = FaultPlan::parse("slow-conn:7", 1).unwrap().arm();
        assert_eq!(armed.conn_delay(), Some(Duration::from_millis(7)));
        assert_eq!(FaultPlan::none().arm().conn_delay(), None);
    }
}
