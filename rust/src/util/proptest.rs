//! Minimal property-testing harness (no `proptest` crate offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; failures report the case index and the
//! sub-seed so a failing input can be reproduced deterministically with
//! [`reproduce`].  Used by the coordinator invariants tests (routing,
//! batching, KV accounting, rejection-sampler exactness).

use crate::util::rng::Rng;

/// Run `prop` over `cases` inputs drawn from `gen`. Panics with the
/// reproducing seed on the first failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let sub_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(sub_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (reproduce with seed {sub_seed}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Re-generate the input for a failing sub-seed (for debugging).
pub fn reproduce<T, G: FnMut(&mut Rng) -> T>(sub_seed: u64, mut gen: G) -> T {
    let mut rng = Rng::new(sub_seed);
    gen(&mut rng)
}

/// Assert helper: turns a boolean + message into the Result the runner wants.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |r| r.range(0, 100),
            |&x| {
                count += 1;
                check(x < 100, "in range")
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(2, 100, |r| r.range(0, 10), |&x| check(x < 5, format!("{x} >= 5")));
    }

    #[test]
    fn reproduce_regenerates_same_input() {
        let a = reproduce(42, |r| r.next_u64());
        let b = reproduce(42, |r| r.next_u64());
        assert_eq!(a, b);
    }
}
