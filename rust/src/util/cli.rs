//! Tiny argument parser (no `clap` offline): `--key value`, `--key=value`,
//! boolean `--flag`, and positional arguments, with typed getters and a
//! generated usage string.

use std::collections::BTreeMap;

/// Declarative flag spec for usage/help output.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value shown in the usage string (None for boolean flags).
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether the boolean `--name` flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String value of `--name`, or `default`.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// usize value of `--name`, or `default` (also on parse failure).
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Like [`Args::usize_or`] but clamped into `[min, max]` — used for
    /// flags with a sane operating envelope (e.g. `--replicas`).
    pub fn usize_clamped_or(
        &self,
        name: &str,
        default: usize,
        min: usize,
        max: usize,
    ) -> usize {
        self.usize_or(name, default).clamp(min, max)
    }

    /// u64 value of `--name`, or `default` (also on parse failure).
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// f64 value of `--name`, or `default` (also on parse failure).
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--buckets 1,4,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Positional (non-flag) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block from flag specs.
pub fn usage(prog: &str, summary: &str, specs: &[FlagSpec]) -> String {
    let mut s = format!("{prog} — {summary}\n\nOptions:\n");
    for f in specs {
        let def = f
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--batch", "8", "--mode=fast"]);
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get("mode"), Some("fast"));
    }

    #[test]
    fn flags_and_positionals() {
        // value-less flags must come last or before another --flag: a bare
        // token after a flag is consumed as its value (documented behavior).
        let a = parse(&["run", "trace.json", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "trace.json".to_string()]);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = parse(&["--n", "42", "--rate", "1.5"]);
        assert_eq!(a.usize_or("n", 0), 42);
        assert_eq!(a.f64_or("rate", 0.0), 1.5);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
    }

    #[test]
    fn clamped_getter_bounds_values() {
        let a = parse(&["--replicas", "999", "--n", "0"]);
        assert_eq!(a.usize_clamped_or("replicas", 1, 1, 64), 64);
        assert_eq!(a.usize_clamped_or("n", 4, 1, 64), 1);
        assert_eq!(a.usize_clamped_or("missing", 4, 1, 64), 4);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--buckets", "1,4,16"]);
        assert_eq!(a.usize_list_or("buckets", &[]), vec![1, 4, 16]);
        assert_eq!(a.usize_list_or("other", &[2]), vec![2]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--check"]);
        assert!(a.flag("check"));
        assert_eq!(a.get("check"), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "dsde",
            "engine",
            &[FlagSpec {
                name: "batch",
                help: "batch size",
                default: Some("8"),
            }],
        );
        assert!(u.contains("--batch"));
        assert!(u.contains("default: 8"));
    }
}
