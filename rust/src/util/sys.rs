//! Thin `extern "C"` shim over the POSIX/Linux readiness APIs (no `libc`
//! crate in the offline vendor set).
//!
//! The event-loop HTTP front-end (`server/event_loop.rs`) needs a handful
//! of primitives the standard library does not expose: `poll(2)` and
//! `epoll(7)` for readiness multiplexing, `pipe(2)` / `eventfd(2)` for a
//! loop waker, `fcntl(2)` to make fds nonblocking, `writev(2)` for
//! vectored zero-copy flushes, `socket(2)`/`setsockopt(2)`/`bind(2)`/
//! `listen(2)` for `SO_REUSEPORT` accept sharding with a configurable
//! backlog, and `setrlimit(2)` to raise the open-file ceiling for large
//! soak runs.  This module declares them directly against the system libc
//! that `std` already links, wraps them in safe Rust, and keeps every
//! `unsafe` block in the crate behind this one file.
//!
//! Two readiness back-ends sit behind the [`Poller`] trait:
//!
//! * [`EpollPoller`] — edge-triggered `epoll`, O(ready) per wakeup.  The
//!   kernel holds the registration set, so the per-event cost is
//!   independent of how many connections are open.
//! * [`PollPoller`] — portable `poll(2)` fallback.  The registration
//!   vector is persistent and updated incrementally on add/modify/remove
//!   (no per-wakeup rebuild), but `poll` itself still scans O(open) fds
//!   in the kernel and the revents sweep is O(open) in userspace.
//!
//! Everything here is POSIX/Linux (the repo's build and CI targets are
//! Linux); sockets themselves stay `std::net` types — only their raw fds
//! are borrowed for the poll set.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::os::raw::{c_int, c_ulong};
use std::os::unix::io::FromRawFd;
use std::sync::atomic::{AtomicBool, Ordering};

/// One entry in a [`poll`] set, laid out exactly like libc's `struct
/// pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// File descriptor to watch (a negative fd is ignored by the kernel).
    pub fd: i32,
    /// Requested readiness events ([`POLLIN`] / [`POLLOUT`] bits).
    pub events: i16,
    /// Returned readiness events (includes error bits even when not
    /// requested).
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for the given interest bits.
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd {
            fd,
            events,
            revents: 0,
        }
    }

    /// Whether any of `bits` came back in `revents`.
    pub fn has(&self, bits: i16) -> bool {
        self.revents & bits != 0
    }
}

/// Readable (or a peer hangup with pending data).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition on the fd (always reported, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer closed the connection (always reported, never requested).
pub const POLLHUP: i16 = 0x010;
/// The fd is not open (always reported, never requested).
pub const POLLNVAL: i16 = 0x020;

/// One entry returned by `epoll_wait(2)`, laid out exactly like libc's
/// `struct epoll_event` (packed on x86-64, natural alignment elsewhere —
/// mirroring the kernel's `__EPOLL_PACKED` attribute).
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    /// Readiness bits (`EPOLLIN` etc. — a `u32` superset of the poll bits).
    pub events: u32,
    /// Caller-chosen cookie returned verbatim with each event.
    pub data: u64,
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLET: u32 = 1 << 31;

const EFD_NONBLOCK: c_int = 0o4000;
const EFD_CLOEXEC: c_int = 0o2000000;

/// `struct rlimit` for get/setrlimit (rlim_t is unsigned long on Linux).
#[repr(C)]
struct RLimit {
    cur: c_ulong,
    max: c_ulong,
}

const RLIMIT_NOFILE: c_int = 7;

mod c {
    use std::os::raw::{c_int, c_uint, c_ulong};

    extern "C" {
        pub fn poll(fds: *mut super::PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        pub fn writev(fd: c_int, iov: *const super::IoVec, iovcnt: c_int) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut super::EpollEvent,
        ) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut super::EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        pub fn getrlimit(resource: c_int, rlim: *mut super::RLimit) -> c_int;
        pub fn setrlimit(resource: c_int, rlim: *const super::RLimit) -> c_int;
        pub fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        pub fn setsockopt(
            fd: c_int,
            level: c_int,
            optname: c_int,
            optval: *const u8,
            optlen: u32,
        ) -> c_int;
        pub fn bind(fd: c_int, addr: *const u8, addrlen: u32) -> c_int;
        pub fn listen(fd: c_int, backlog: c_int) -> c_int;
    }
}

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const O_NONBLOCK: c_int = 0o4000;

/// Block until at least one entry is ready, `timeout_ms` elapses
/// (`-1` = forever, `0` = nonblocking), or a signal arrives.  Retries
/// `EINTR` internally; returns the number of entries with nonzero
/// `revents`.
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        // SAFETY: `fds` is a live, exclusively borrowed slice of repr(C)
        // pollfd-compatible structs; the kernel writes only `revents`.
        let rc = unsafe { c::poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

fn set_nonblocking(fd: c_int) -> io::Result<()> {
    // SAFETY: plain fcntl flag read/modify/write on an fd we own.
    let flags = unsafe { c::fcntl(fd, F_GETFL) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    let rc = unsafe { c::fcntl(fd, F_SETFL, flags | O_NONBLOCK) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Best-effort raise of the process open-file soft limit toward `want`.
///
/// Returns the soft limit in effect afterwards (which may be below `want`
/// when the hard limit caps it).  Large-fan-out soaks and benches call
/// this before opening tens of thousands of sockets; everything else can
/// ignore it.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = RLimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid out-struct matching the kernel layout.
    if unsafe { c::getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return Err(io::Error::last_os_error());
    }
    if u64::from(lim.cur) >= want {
        return Ok(lim.cur as u64);
    }
    let new_cur = (want as c_ulong).min(lim.max);
    let new = RLimit {
        cur: new_cur,
        max: lim.max,
    };
    // SAFETY: passing a valid, fully initialised rlimit struct.
    if unsafe { c::setrlimit(RLIMIT_NOFILE, &new) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(new_cur as u64)
}

/// One scatter/gather entry for [`writev`], laid out exactly like libc's
/// `struct iovec`.
///
/// Holds a raw pointer: an `IoVec` is only valid while the slice it was
/// built from is borrowed, so build the array immediately before the
/// syscall and let it die right after (the [`FrameQueue`] flush does
/// exactly that).
///
/// [`FrameQueue`]: crate::util::bufpool::FrameQueue
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct IoVec {
    /// First byte of the chunk.
    pub base: *const u8,
    /// Chunk length in bytes.
    pub len: usize,
}

impl IoVec {
    /// Borrow `bytes` as one scatter/gather entry.
    pub fn from_slice(bytes: &[u8]) -> IoVec {
        IoVec {
            base: bytes.as_ptr(),
            len: bytes.len(),
        }
    }
}

/// Linux's `IOV_MAX`: the most iovec entries one `writev(2)` accepts.
pub const IOV_MAX: usize = 1024;

/// Gather-write `iov` to `fd` in one syscall.  Retries `EINTR`
/// internally; returns the number of bytes written (possibly short) or
/// the raw OS error (`WouldBlock` on a full nonblocking socket buffer).
/// At most [`IOV_MAX`] entries are passed through; callers batching more
/// must loop.
pub fn writev(fd: i32, iov: &[IoVec]) -> io::Result<usize> {
    let n = iov.len().min(IOV_MAX);
    loop {
        // SAFETY: `iov` borrows live slices for the duration of this call;
        // the kernel only reads from them.
        let rc = unsafe { c::writev(fd, iov.as_ptr(), n as c_int) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

const AF_INET: c_int = 2;
const AF_INET6: c_int = 10;
const SOCK_STREAM: c_int = 1;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;

/// `struct sockaddr_in` (fields in network byte order where the ABI says
/// so).
#[repr(C)]
struct SockAddrIn {
    family: u16,
    port_be: u16,
    addr_be: u32,
    zero: [u8; 8],
}

/// `struct sockaddr_in6`.
#[repr(C)]
struct SockAddrIn6 {
    family: u16,
    port_be: u16,
    flowinfo: u32,
    addr: [u8; 16],
    scope_id: u32,
}

/// Closes the wrapped fd unless disarmed — keeps the error paths of
/// [`bind_listener`] leak-free.
struct FdGuard(c_int);

impl FdGuard {
    fn release(self) -> c_int {
        let fd = self.0;
        std::mem::forget(self);
        fd
    }
}

impl Drop for FdGuard {
    fn drop(&mut self) {
        // SAFETY: closing an fd this guard exclusively owns.
        unsafe {
            c::close(self.0);
        }
    }
}

fn sockopt_on(fd: c_int, opt: c_int) -> io::Result<()> {
    let one: c_int = 1;
    // SAFETY: passing a live 4-byte int option value, as SOL_SOCKET
    // boolean options require.
    let rc = unsafe {
        c::setsockopt(
            fd,
            SOL_SOCKET,
            opt,
            &one as *const c_int as *const u8,
            std::mem::size_of::<c_int>() as u32,
        )
    };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Create a listening TCP socket on `addr` with an explicit `backlog`,
/// optionally tagged `SO_REUSEPORT`.
///
/// `std::net::TcpListener::bind` hides both knobs this repo needs: the
/// listen backlog (std hardcodes 128, which clamps accept bursts well
/// below soak arrival rates) and `SO_REUSEPORT` (which lets every loop
/// shard bind the same address so the kernel itself distributes
/// accepts).  `SO_REUSEADDR` is always set, matching std's behaviour.
/// Fails — with the socket closed — when the kernel rejects
/// `SO_REUSEPORT`; `--accept auto` treats that as "fall back to handoff".
pub fn bind_listener(addr: SocketAddr, backlog: i32, reuseport: bool) -> io::Result<TcpListener> {
    let domain = match addr {
        SocketAddr::V4(_) => AF_INET,
        SocketAddr::V6(_) => AF_INET6,
    };
    // SAFETY: plain syscall, no pointers.
    let fd = unsafe { c::socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let guard = FdGuard(fd);
    sockopt_on(fd, SO_REUSEADDR)?;
    if reuseport {
        sockopt_on(fd, SO_REUSEPORT)?;
    }
    let rc = match addr {
        SocketAddr::V4(v4) => {
            let sa = SockAddrIn {
                family: AF_INET as u16,
                port_be: v4.port().to_be(),
                addr_be: u32::from(*v4.ip()).to_be(),
                zero: [0; 8],
            };
            // SAFETY: `sa` is a live repr(C) sockaddr_in; the kernel
            // copies it out during the call.
            unsafe {
                c::bind(
                    fd,
                    &sa as *const SockAddrIn as *const u8,
                    std::mem::size_of::<SockAddrIn>() as u32,
                )
            }
        }
        SocketAddr::V6(v6) => {
            let sa = SockAddrIn6 {
                family: AF_INET6 as u16,
                port_be: v6.port().to_be(),
                flowinfo: v6.flowinfo(),
                addr: v6.ip().octets(),
                scope_id: v6.scope_id(),
            };
            // SAFETY: `sa` is a live repr(C) sockaddr_in6.
            unsafe {
                c::bind(
                    fd,
                    &sa as *const SockAddrIn6 as *const u8,
                    std::mem::size_of::<SockAddrIn6>() as u32,
                )
            }
        }
    };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    // SAFETY: plain syscall on the fd we own.
    if unsafe { c::listen(fd, backlog) } != 0 {
        return Err(io::Error::last_os_error());
    }
    // SAFETY: the fd is a freshly bound+listening TCP socket whose sole
    // owner is handed to the TcpListener.
    Ok(unsafe { TcpListener::from_raw_fd(guard.release()) })
}

/// One readiness event reported by a [`Poller`], back-end neutral.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The registration cookie passed to [`Poller::add`].
    pub token: u64,
    /// The fd is readable.
    pub readable: bool,
    /// The fd is writable.
    pub writable: bool,
    /// Error condition (poll's `POLLERR`/`POLLNVAL`, epoll's `EPOLLERR`).
    pub error: bool,
    /// Peer hangup (both directions gone — a half-close with data still
    /// flowing shows up as readable, not hup, under both back-ends).
    pub hup: bool,
}

/// Readiness multiplexer: register fds with interest bits and a token,
/// then [`wait`](Poller::wait) for events.
///
/// Interest is expressed with the portable [`POLLIN`]/[`POLLOUT`] bits
/// for both back-ends.  `edge` requests edge-triggered delivery where the
/// back-end supports it ([`EpollPoller`]); the caller must then drain the
/// fd to `WouldBlock` on every event or readiness is lost until the next
/// edge.  [`PollPoller`] ignores `edge` and is always level-triggered —
/// correct for drain-to-`WouldBlock` callers too, just chattier.
pub trait Poller: Send {
    /// Register `fd` under `token` with the given interest bits.
    fn add(&mut self, fd: i32, token: u64, interest: i16, edge: bool) -> io::Result<()>;
    /// Change the interest bits of an already registered fd.  Under
    /// edge-triggered epoll this re-arms the fd: readiness that currently
    /// holds is reported again, so interest changes never lose edges.
    fn modify(&mut self, fd: i32, token: u64, interest: i16, edge: bool) -> io::Result<()>;
    /// Drop the registration for `fd` (call before closing the fd so the
    /// poll fallback's persistent set stays in sync).
    fn remove(&mut self, fd: i32) -> io::Result<()>;
    /// Block up to `timeout_ms` (`-1` = forever) and append ready events
    /// to `out` (cleared first).  Retries `EINTR` internally.
    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()>;
    /// Back-end name for metrics/logs: `"epoll"` or `"poll"`.
    fn name(&self) -> &'static str;
}

/// Edge-triggered `epoll(7)` back-end: the kernel owns the interest set,
/// each wakeup costs O(ready) rather than O(open).
pub struct EpollPoller {
    epfd: c_int,
    buf: Vec<EpollEvent>,
}

impl EpollPoller {
    /// Create an epoll instance.  Fails on kernels/platforms without
    /// epoll — callers resolving `--poller auto` treat that as "fall back
    /// to [`PollPoller`]".
    pub fn new() -> io::Result<EpollPoller> {
        // SAFETY: plain syscall, no pointers.
        let epfd = unsafe { c::epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollPoller {
            epfd,
            buf: Vec::with_capacity(1024),
        })
    }

    fn ctl(&mut self, op: c_int, fd: i32, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        // SAFETY: `ev` is a live struct matching the kernel layout; the
        // kernel copies it out during the call.
        if unsafe { c::epoll_ctl(self.epfd, op, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }
}

fn epoll_bits(interest: i16, edge: bool) -> u32 {
    let mut ev = 0u32;
    if interest & POLLIN != 0 {
        ev |= EPOLLIN;
    }
    if interest & POLLOUT != 0 {
        ev |= EPOLLOUT;
    }
    if edge {
        ev |= EPOLLET;
    }
    ev
}

impl Poller for EpollPoller {
    fn add(&mut self, fd: i32, token: u64, interest: i16, edge: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, epoll_bits(interest, edge), token)
    }

    fn modify(&mut self, fd: i32, token: u64, interest: i16, edge: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, epoll_bits(interest, edge), token)
    }

    fn remove(&mut self, fd: i32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        out.clear();
        let n = loop {
            let cap = self.buf.capacity().max(64);
            // SAFETY: the kernel writes at most `cap` events into the
            // buffer's allocation; we set the length to what it reports.
            let rc = unsafe {
                c::epoll_wait(self.epfd, self.buf.as_mut_ptr(), cap as c_int, timeout_ms)
            };
            if rc >= 0 {
                // SAFETY: epoll_wait initialised exactly `rc` entries.
                unsafe { self.buf.set_len(rc as usize) };
                break rc as usize;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        for i in 0..n {
            let ev = self.buf[i];
            let bits = ev.events;
            out.push(Event {
                token: ev.data,
                readable: bits & EPOLLIN != 0,
                writable: bits & EPOLLOUT != 0,
                error: bits & EPOLLERR != 0,
                hup: bits & EPOLLHUP != 0,
            });
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "epoll"
    }
}

impl Drop for EpollPoller {
    fn drop(&mut self) {
        // SAFETY: closing the epoll fd this struct exclusively owns.
        unsafe {
            c::close(self.epfd);
        }
    }
}

/// Portable `poll(2)` back-end with a persistent registration vector.
///
/// Registrations are updated incrementally on add/modify/remove — the
/// historical per-wakeup `clear()` + full repush is gone — but `poll`
/// itself remains O(open) per call, which is exactly why [`EpollPoller`]
/// exists.
pub struct PollPoller {
    pfds: Vec<PollFd>,
    tokens: Vec<u64>,
    index: HashMap<i32, usize>,
}

impl PollPoller {
    /// Create an empty registration set.
    pub fn new() -> PollPoller {
        PollPoller {
            pfds: Vec::new(),
            tokens: Vec::new(),
            index: HashMap::new(),
        }
    }

    /// Number of registered fds (for tests / diagnostics).
    pub fn len(&self) -> usize {
        self.pfds.len()
    }

    /// Whether no fds are registered.
    pub fn is_empty(&self) -> bool {
        self.pfds.is_empty()
    }
}

impl Poller for PollPoller {
    fn add(&mut self, fd: i32, token: u64, interest: i16, _edge: bool) -> io::Result<()> {
        if self.index.contains_key(&fd) {
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                "fd already registered",
            ));
        }
        self.index.insert(fd, self.pfds.len());
        self.pfds.push(PollFd::new(fd, interest));
        self.tokens.push(token);
        Ok(())
    }

    fn modify(&mut self, fd: i32, token: u64, interest: i16, _edge: bool) -> io::Result<()> {
        let &i = self
            .index
            .get(&fd)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
        self.pfds[i].events = interest;
        self.tokens[i] = token;
        Ok(())
    }

    fn remove(&mut self, fd: i32) -> io::Result<()> {
        let i = self
            .index
            .remove(&fd)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
        self.pfds.swap_remove(i);
        self.tokens.swap_remove(i);
        if i < self.pfds.len() {
            self.index.insert(self.pfds[i].fd, i);
        }
        Ok(())
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        out.clear();
        let ready = poll(&mut self.pfds, timeout_ms)?;
        if ready == 0 {
            return Ok(());
        }
        for (i, p) in self.pfds.iter().enumerate() {
            if p.revents == 0 {
                continue;
            }
            out.push(Event {
                token: self.tokens[i],
                readable: p.has(POLLIN),
                writable: p.has(POLLOUT),
                error: p.has(POLLERR | POLLNVAL),
                hup: p.has(POLLHUP),
            });
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "poll"
    }
}

/// Loop waker with coalesced pokes: lets any thread interrupt a
/// [`poll`]/[`Poller::wait`] sleep.
///
/// Backed by `eventfd(2)` when available (one fd, one counter) with a
/// nonblocking self-pipe as the portable fallback.  The read end is
/// registered in the poll set alongside the sockets; any thread holding a
/// clone of the `Arc<Waker>` calls [`Waker::wake`] to make the loop's
/// wait return immediately.
///
/// **Coalescing protocol.**  A `wake-pending` flag makes a burst of wakes
/// cost one syscall: `wake()` writes to the fd only on the flag's 0→1
/// transition; while the flag is set, further wakes are a single atomic
/// swap.  The consumer must call [`Waker::drain`] *before* processing the
/// work the wakes announced — `drain` empties the fd and only then clears
/// the flag, so a wake swallowed by the flag always precedes a drain whose
/// caller then observes the published work (both sides use `AcqRel`
/// read-modify-writes on the flag, which totally orders them).  Producers
/// must publish their work (ring push / channel send) *before* calling
/// `wake()`.
#[derive(Debug)]
pub struct Waker {
    read_fd: c_int,
    write_fd: c_int,
    pending: AtomicBool,
}

impl Waker {
    /// Create a waker: `eventfd` when the kernel provides it, otherwise a
    /// nonblocking self-pipe pair.
    pub fn new() -> io::Result<Waker> {
        // SAFETY: plain syscall, no pointers.
        let efd = unsafe { c::eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
        if efd >= 0 {
            return Ok(Waker {
                read_fd: efd,
                write_fd: efd,
                pending: AtomicBool::new(false),
            });
        }
        let mut fds: [c_int; 2] = [0; 2];
        // SAFETY: `fds` is a valid out-array of two c_ints.
        let rc = unsafe { c::pipe(fds.as_mut_ptr()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        let waker = Waker {
            read_fd: fds[0],
            write_fd: fds[1],
            pending: AtomicBool::new(false),
        };
        set_nonblocking(waker.read_fd)?;
        set_nonblocking(waker.write_fd)?;
        Ok(waker)
    }

    /// The read end, for registering in a poll set with [`POLLIN`].
    pub fn read_fd(&self) -> i32 {
        self.read_fd
    }

    /// Interrupt the poller.  Never blocks, and a burst of wakes between
    /// two drains performs exactly one fd write (the rest coalesce on the
    /// pending flag).
    pub fn wake(&self) {
        if self.pending.swap(true, Ordering::AcqRel) {
            return; // a wake is already in flight; the fd has its byte
        }
        let buf = 1u64.to_ne_bytes();
        // SAFETY: writing 8 bytes from a live stack buffer to an fd we
        // own (an eventfd requires exactly a u64; a pipe takes any bytes).
        let _ = unsafe { c::write(self.write_fd, buf.as_ptr(), buf.len()) };
    }

    /// Consume pending wake-up bytes and reset the coalescing flag (call
    /// after the poller reports the read end readable, *before* handling
    /// the work the wakes announced).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reading into a live stack buffer from an fd we own.
            let n = unsafe { c::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                break; // empty (EAGAIN), EOF, or error: nothing left
            }
        }
        // Clear only after the fd is empty: a racing wake in the window
        // between the last read and this swap skips its write (flag still
        // set), and our caller pumps the published work right after.
        self.pending.swap(false, Ordering::AcqRel);
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: closing fds this struct exclusively owns.
        unsafe {
            c::close(self.read_fd);
            if self.write_fd != self.read_fd {
                c::close(self.write_fd);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_times_out_on_idle_pipe() {
        let w = Waker::new().unwrap();
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, 0).unwrap();
        assert_eq!(n, 0);
        assert!(!fds[0].has(POLLIN));
    }

    #[test]
    fn wake_makes_pipe_readable_and_drain_clears_it() {
        let w = Waker::new().unwrap();
        w.wake();
        w.wake(); // coalesced wakes are fine
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].has(POLLIN));
        w.drain();
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn wake_works_again_after_drain_resets_coalescing() {
        let w = Waker::new().unwrap();
        for _ in 0..3 {
            w.wake();
            w.wake(); // second wake coalesces onto the pending flag
            let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 1000).unwrap(), 1, "wake after drain lost");
            w.drain();
            let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 0).unwrap(), 0);
        }
    }

    #[test]
    fn wake_from_another_thread_interrupts_poll() {
        let w = std::sync::Arc::new(Waker::new().unwrap());
        let w2 = w.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w2.wake();
        });
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let t0 = std::time::Instant::now();
        let n = poll(&mut fds, 5000).unwrap();
        assert_eq!(n, 1);
        assert!(t0.elapsed().as_secs() < 5, "poll returned via wake, not timeout");
        t.join().unwrap();
    }

    #[test]
    fn wake_never_blocks_even_when_pipe_is_full() {
        let w = Waker::new().unwrap();
        // far more wakes than any pipe buffers; all but the first coalesce
        // and every one must return immediately
        for _ in 0..100_000 {
            w.wake();
        }
        w.drain();
    }

    fn poller_reports_waker_readiness(mut p: Box<dyn Poller>) {
        let w = Waker::new().unwrap();
        p.add(w.read_fd(), 7, POLLIN, true).unwrap();
        let mut evs = Vec::new();
        p.wait(0, &mut evs).unwrap();
        assert!(evs.is_empty(), "{}: idle waker reported ready", p.name());
        w.wake();
        p.wait(1000, &mut evs).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].readable);
        w.drain();
        p.wait(0, &mut evs).unwrap();
        assert!(evs.is_empty(), "{}: drained waker still ready", p.name());
        // a fresh wake is a fresh edge — must be reported again
        w.wake();
        p.wait(1000, &mut evs).unwrap();
        assert_eq!(evs.len(), 1, "{}: second edge lost", p.name());
        p.remove(w.read_fd()).unwrap();
        w.wake();
        p.wait(0, &mut evs).unwrap();
        assert!(evs.is_empty(), "{}: removed fd still reported", p.name());
    }

    #[test]
    fn epoll_poller_reports_waker_readiness() {
        poller_reports_waker_readiness(Box::new(EpollPoller::new().unwrap()));
    }

    #[test]
    fn poll_poller_reports_waker_readiness() {
        poller_reports_waker_readiness(Box::new(PollPoller::new()));
    }

    #[test]
    fn epoll_edge_triggered_reports_once_until_rearmed() {
        let mut p = EpollPoller::new().unwrap();
        let w = Waker::new().unwrap();
        p.add(w.read_fd(), 1, POLLIN, true).unwrap();
        w.wake();
        let mut evs = Vec::new();
        p.wait(1000, &mut evs).unwrap();
        assert_eq!(evs.len(), 1);
        // edge consumed without draining the fd: no second report...
        p.wait(0, &mut evs).unwrap();
        assert!(evs.is_empty(), "edge-triggered epoll re-reported a seen edge");
        // ...until EPOLL_CTL_MOD re-arms the registration, which reports
        // readiness that currently holds (the event-loop relies on this
        // when it changes a connection's interest set).
        p.modify(w.read_fd(), 1, POLLIN, true).unwrap();
        p.wait(1000, &mut evs).unwrap();
        assert_eq!(evs.len(), 1, "EPOLL_CTL_MOD did not re-arm readiness");
    }

    #[test]
    fn poll_poller_registrations_update_incrementally() {
        let mut p = PollPoller::new();
        let a = Waker::new().unwrap();
        let b = Waker::new().unwrap();
        let c = Waker::new().unwrap();
        p.add(a.read_fd(), 10, POLLIN, false).unwrap();
        p.add(b.read_fd(), 11, POLLIN, false).unwrap();
        p.add(c.read_fd(), 12, POLLIN, false).unwrap();
        assert_eq!(p.len(), 3);
        assert!(p.add(a.read_fd(), 99, POLLIN, false).is_err());
        // remove the first entry: swap-remove moves the last into its slot
        // and the index map must follow
        p.remove(a.read_fd()).unwrap();
        assert_eq!(p.len(), 2);
        b.wake();
        c.wake();
        let mut evs = Vec::new();
        p.wait(1000, &mut evs).unwrap();
        let mut tokens: Vec<u64> = evs.iter().map(|e| e.token).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, vec![11, 12]);
        // interest change to "nothing" suppresses readiness
        p.modify(b.read_fd(), 11, 0, false).unwrap();
        p.wait(0, &mut evs).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 12);
        assert!(p.remove(a.read_fd()).is_err(), "double remove must fail");
    }

    #[test]
    fn writev_gathers_multiple_slices_in_one_call() {
        use std::io::Read;
        use std::net::{TcpListener, TcpStream};
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let parts: [&[u8]; 3] = [b"alpha-", b"beta-", b"gamma"];
        let iov: Vec<IoVec> = parts.iter().map(|p| IoVec::from_slice(p)).collect();
        use std::os::unix::io::AsRawFd;
        let n = writev(tx.as_raw_fd(), &iov).unwrap();
        assert_eq!(n, 16);
        let mut got = vec![0u8; 16];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"alpha-beta-gamma");
    }

    #[test]
    fn writev_on_full_nonblocking_socket_returns_would_block() {
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (_rx, _) = l.accept().unwrap();
        tx.set_nonblocking(true).unwrap();
        // nobody reads `_rx`: keep writing until the socket buffer fills
        let chunk = vec![0u8; 64 * 1024];
        let iov = [IoVec::from_slice(&chunk)];
        let err = loop {
            match writev(tx.as_raw_fd(), &iov) {
                Ok(_) => continue,
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
    }

    #[test]
    fn bind_listener_accepts_connections() {
        use std::io::{Read, Write};
        use std::net::TcpStream;
        let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
        let l = bind_listener(addr, 128, false).unwrap();
        let bound = l.local_addr().unwrap();
        assert_ne!(bound.port(), 0);
        let mut tx = TcpStream::connect(bound).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        tx.write_all(b"ping").unwrap();
        let mut got = [0u8; 4];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"ping");
    }

    #[test]
    fn reuseport_allows_two_listeners_on_one_port() {
        let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
        let first = match bind_listener(addr, 128, true) {
            Ok(l) => l,
            // kernels without SO_REUSEPORT: the fallback path is exactly
            // what `--accept auto` exercises, nothing more to assert here
            Err(_) => return,
        };
        let port = first.local_addr().unwrap().port();
        let again: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let second = bind_listener(again, 128, true).unwrap();
        assert_eq!(second.local_addr().unwrap().port(), port);
        // without SO_REUSEPORT the same bind must be refused
        assert!(bind_listener(again, 128, false).is_err());
    }

    #[test]
    fn raise_nofile_limit_is_monotonic() {
        // asking for a tiny target must never lower the current limit
        let before = raise_nofile_limit(1).unwrap();
        assert!(before >= 1);
        let after = raise_nofile_limit(before).unwrap();
        assert!(after >= before);
    }
}
