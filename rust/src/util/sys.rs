//! Thin `extern "C"` shim over the POSIX readiness API (no `libc` crate in
//! the offline vendor set).
//!
//! The event-loop HTTP front-end (`server/event_loop.rs`) needs exactly
//! three primitives the standard library does not expose: `poll(2)` for
//! readiness multiplexing, `pipe(2)` for a self-pipe waker, and
//! `fcntl(2)` to make the pipe ends nonblocking.  This module declares
//! them directly against the system libc that `std` already links, wraps
//! them in safe Rust, and keeps every `unsafe` block in the crate behind
//! this one file.
//!
//! Everything here is POSIX (the repo's build and CI targets are Linux);
//! sockets themselves stay `std::net` types — only their raw fds are
//! borrowed for the poll set.

use std::io;
use std::os::raw::{c_int, c_ulong};

/// One entry in a [`poll`] set, laid out exactly like libc's `struct
/// pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// File descriptor to watch (a negative fd is ignored by the kernel).
    pub fd: i32,
    /// Requested readiness events ([`POLLIN`] / [`POLLOUT`] bits).
    pub events: i16,
    /// Returned readiness events (includes error bits even when not
    /// requested).
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for the given interest bits.
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd {
            fd,
            events,
            revents: 0,
        }
    }

    /// Whether any of `bits` came back in `revents`.
    pub fn has(&self, bits: i16) -> bool {
        self.revents & bits != 0
    }
}

/// Readable (or a peer hangup with pending data).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition on the fd (always reported, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer closed the connection (always reported, never requested).
pub const POLLHUP: i16 = 0x010;
/// The fd is not open (always reported, never requested).
pub const POLLNVAL: i16 = 0x020;

mod c {
    use std::os::raw::{c_int, c_ulong};

    extern "C" {
        pub fn poll(fds: *mut super::PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    }
}

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const O_NONBLOCK: c_int = 0o4000;

/// Block until at least one entry is ready, `timeout_ms` elapses
/// (`-1` = forever, `0` = nonblocking), or a signal arrives.  Retries
/// `EINTR` internally; returns the number of entries with nonzero
/// `revents`.
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        // SAFETY: `fds` is a live, exclusively borrowed slice of repr(C)
        // pollfd-compatible structs; the kernel writes only `revents`.
        let rc = unsafe { c::poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

fn set_nonblocking(fd: c_int) -> io::Result<()> {
    // SAFETY: plain fcntl flag read/modify/write on an fd we own.
    let flags = unsafe { c::fcntl(fd, F_GETFL) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    let rc = unsafe { c::fcntl(fd, F_SETFL, flags | O_NONBLOCK) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Self-pipe waker: lets any thread interrupt a [`poll`] sleep.
///
/// The read end is registered in the poll set alongside the sockets; any
/// thread holding a clone of the `Arc<Waker>` calls [`Waker::wake`] to
/// make the loop's `poll` return immediately.  Both pipe ends are
/// nonblocking, so `wake` never blocks: once the pipe's buffer holds a
/// byte the wake-up is already guaranteed and further writes may be
/// dropped (`EAGAIN`) without losing anything.  This is how engine
/// replica threads notify the event loop that a `StreamEvent` or
/// `FinishedRequest` is ready without any blocking `recv` — see
/// `EngineRouter::submit_streaming_with_waker`.
#[derive(Debug)]
pub struct Waker {
    read_fd: c_int,
    write_fd: c_int,
}

impl Waker {
    /// Create a nonblocking self-pipe pair.
    pub fn new() -> io::Result<Waker> {
        let mut fds: [c_int; 2] = [0; 2];
        // SAFETY: `fds` is a valid out-array of two c_ints.
        let rc = unsafe { c::pipe(fds.as_mut_ptr()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        let waker = Waker {
            read_fd: fds[0],
            write_fd: fds[1],
        };
        set_nonblocking(waker.read_fd)?;
        set_nonblocking(waker.write_fd)?;
        Ok(waker)
    }

    /// The read end, for registering in a poll set with [`POLLIN`].
    pub fn read_fd(&self) -> i32 {
        self.read_fd
    }

    /// Interrupt the poller.  Never blocks; a full pipe means a wake-up
    /// is already pending, so the dropped byte is harmless.
    pub fn wake(&self) {
        let byte = [1u8];
        // SAFETY: writing one byte from a live stack buffer to an fd we
        // own; the nonblocking pipe returns EAGAIN instead of blocking.
        let _ = unsafe { c::write(self.write_fd, byte.as_ptr(), 1) };
    }

    /// Consume all pending wake-up bytes (call after `poll` reports the
    /// read end readable, before handling the work the wakes announced).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reading into a live stack buffer from an fd we own.
            let n = unsafe { c::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                break; // empty (EAGAIN), EOF, or error: nothing left
            }
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: closing fds this struct exclusively owns.
        unsafe {
            c::close(self.read_fd);
            c::close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_times_out_on_idle_pipe() {
        let w = Waker::new().unwrap();
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, 0).unwrap();
        assert_eq!(n, 0);
        assert!(!fds[0].has(POLLIN));
    }

    #[test]
    fn wake_makes_pipe_readable_and_drain_clears_it() {
        let w = Waker::new().unwrap();
        w.wake();
        w.wake(); // coalesced wakes are fine
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].has(POLLIN));
        w.drain();
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn wake_from_another_thread_interrupts_poll() {
        let w = std::sync::Arc::new(Waker::new().unwrap());
        let w2 = w.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w2.wake();
        });
        let mut fds = [PollFd::new(w.read_fd(), POLLIN)];
        let t0 = std::time::Instant::now();
        let n = poll(&mut fds, 5000).unwrap();
        assert_eq!(n, 1);
        assert!(t0.elapsed().as_secs() < 5, "poll returned via wake, not timeout");
        t.join().unwrap();
    }

    #[test]
    fn wake_never_blocks_even_when_pipe_is_full() {
        let w = Waker::new().unwrap();
        // a linux pipe buffers 64KiB; far more wakes than that must all
        // return immediately
        for _ in 0..100_000 {
            w.wake();
        }
        w.drain();
    }
}
