//! Fixed-capacity ring buffers.
//!
//! [`Ring`] is the f64 buffer used for the per-sequence KLD signal windows
//! (paper Fig. 5: short N=10 and long N=30 histories).  Pushing beyond
//! capacity evicts the oldest entry; iteration order is most-recent-first to
//! line up with the paper's reverse index i (Eq. 5).
//!
//! [`RingBuf`] is the generic retention window used by
//! [`crate::engine::metrics::EngineMetrics`] to bound per-request metric
//! growth under sustained serving traffic: the newest `cap` items are kept,
//! older ones are evicted, and iteration is oldest-first (insertion order).

use std::collections::VecDeque;

/// Generic fixed-capacity retention window: keeps the `cap` most recent
/// items, iterates oldest → newest.
#[derive(Clone, Debug)]
pub struct RingBuf<T> {
    buf: VecDeque<T>,
    cap: usize,
    /// total items ever pushed (including evicted ones)
    pushed: u64,
}

impl<T> RingBuf<T> {
    /// Create a window retaining the `cap` most recent items (cap > 0).
    pub fn new(cap: usize) -> RingBuf<T> {
        assert!(cap > 0);
        RingBuf {
            buf: VecDeque::with_capacity(cap.min(1024)),
            cap,
            pushed: 0,
        }
    }

    /// Append, evicting the oldest item when at capacity.
    pub fn push(&mut self, item: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(item);
        self.pushed += 1;
    }

    /// Items currently retained.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The retention capacity set at construction.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total items ever pushed, including evicted ones.
    pub fn total_pushed(&self) -> u64 {
        self.pushed
    }

    /// Number of items evicted by the retention window so far.
    pub fn evicted(&self) -> u64 {
        self.pushed - self.buf.len() as u64
    }

    /// Iterate oldest → newest over the retained window.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }

    /// Drop all retained items (the total-pushed counter is kept).
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

impl<'a, T> IntoIterator for &'a RingBuf<T> {
    type Item = &'a T;
    type IntoIter = std::collections::vec_deque::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.buf.iter()
    }
}

/// Fixed-capacity ring buffer of f64 with most-recent-first reads.
#[derive(Clone, Debug)]
pub struct Ring {
    buf: Vec<f64>,
    cap: usize,
    head: usize, // next write slot
    len: usize,
}

impl Ring {
    /// Create a ring holding up to `cap` values (cap > 0).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Ring {
            buf: vec![0.0; cap],
            cap,
            head: 0,
            len: 0,
        }
    }

    /// Append, evicting the oldest value when at capacity.
    pub fn push(&mut self, x: f64) {
        self.buf[self.head] = x;
        self.head = (self.head + 1) % self.cap;
        if self.len < self.cap {
            self.len += 1;
        }
    }

    /// Values currently held.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether nothing has been pushed (or the ring was cleared).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the ring holds `capacity` values.
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// The capacity set at construction.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Forget all values.
    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }

    /// k-th most recent value (k = 0 is the newest). None if out of range.
    pub fn recent(&self, k: usize) -> Option<f64> {
        if k >= self.len {
            return None;
        }
        let idx = (self.head + self.cap - 1 - k) % self.cap;
        Some(self.buf[idx])
    }

    /// Copy out up to `n` most recent values, newest first.
    pub fn latest(&self, n: usize) -> Vec<f64> {
        (0..n.min(self.len)).map(|k| self.recent(k).unwrap()).collect()
    }

    /// Iterate newest → oldest.
    pub fn iter_recent(&self) -> impl Iterator<Item = f64> + '_ {
        (0..self.len).map(move |k| self.recent(k).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_recent_order() {
        let mut r = Ring::new(3);
        r.push(1.0);
        r.push(2.0);
        r.push(3.0);
        assert_eq!(r.recent(0), Some(3.0));
        assert_eq!(r.recent(1), Some(2.0));
        assert_eq!(r.recent(2), Some(1.0));
        assert_eq!(r.recent(3), None);
    }

    #[test]
    fn eviction_keeps_newest() {
        let mut r = Ring::new(3);
        for i in 1..=5 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.latest(3), vec![5.0, 4.0, 3.0]);
    }

    #[test]
    fn latest_truncates_to_len() {
        let mut r = Ring::new(10);
        r.push(7.0);
        assert_eq!(r.latest(5), vec![7.0]);
    }

    #[test]
    fn clear_resets() {
        let mut r = Ring::new(2);
        r.push(1.0);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.recent(0), None);
    }

    #[test]
    fn full_flag() {
        let mut r = Ring::new(2);
        assert!(!r.is_full());
        r.push(0.0);
        r.push(0.0);
        assert!(r.is_full());
    }

    #[test]
    fn iter_matches_latest() {
        let mut r = Ring::new(4);
        for i in 0..6 {
            r.push(i as f64);
        }
        let via_iter: Vec<f64> = r.iter_recent().collect();
        assert_eq!(via_iter, r.latest(4));
    }

    #[test]
    fn ringbuf_bounded_and_ordered() {
        let mut r: RingBuf<u32> = RingBuf::new(3);
        for i in 0..7u32 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![4, 5, 6]);
        assert_eq!(r.total_pushed(), 7);
        assert_eq!(r.evicted(), 4);
    }

    #[test]
    fn ringbuf_under_capacity_keeps_everything() {
        let mut r: RingBuf<&str> = RingBuf::new(8);
        r.push("a");
        r.push("b");
        assert_eq!(r.len(), 2);
        assert_eq!(r.evicted(), 0);
        assert!(!r.is_empty());
        let mut seen = 0;
        for _ in &r {
            seen += 1;
        }
        assert_eq!(seen, 2);
    }

    #[test]
    fn ringbuf_clear_keeps_pushed_total() {
        let mut r: RingBuf<u8> = RingBuf::new(2);
        r.push(1);
        r.push(2);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.total_pushed(), 2);
    }
}
