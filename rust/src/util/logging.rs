//! Leveled stderr logger with elapsed-time stamps.  Level is set once at
//! startup (from `--log-level` or `DSDE_LOG`); macros are free when the
//! level is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or data-losing conditions; always shown.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Lifecycle events (the default level).
    Info = 2,
    /// Per-operation detail (enable with `DSDE_LOG=debug`).
    Debug = 3,
    /// Hot-path tracing.
    Trace = 4,
}

impl Level {
    /// Parse a case-insensitive level name.
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Fixed-width tag used in the log line prefix.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global level (also reads DSDE_LOG env on first call via init).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from environment (DSDE_LOG=debug etc.). Idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("DSDE_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

/// Whether messages at `level` currently pass the filter.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one log line (the `log_*!` macros route here).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {}] {args}", level.tag());
}

/// Log at error level (always shown).
#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($a)*)) } }
/// Log at warn level.
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($a)*)) } }
/// Log at info level.
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($a)*)) } }
/// Log at debug level (gated by `DSDE_LOG`).
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn ordering_of_levels() {
        assert!(Level::Error < Level::Trace);
    }
}
