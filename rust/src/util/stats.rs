//! Statistics helpers: plain + exponentially-weighted moments (paper
//! Eq. 6–7), Pearson correlation (paper Table 2), percentiles, and an
//! online Welford accumulator for metrics.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Weighted mean over (value, weight) pairs — paper Eq. 6.
pub fn weighted_mean(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return 0.0;
    }
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| v * w)
        .sum::<f64>()
        / wsum
}

/// Weighted variance over (value, weight) pairs — paper Eq. 7.
pub fn weighted_variance(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return 0.0;
    }
    let wm = weighted_mean(values, weights);
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| w * (v - wm) * (v - wm))
        .sum::<f64>()
        / wsum
}

/// Exponential-decay weights α_i = δ^(i-1) for i = 1..=n where i == 1 is the
/// most recent observation — paper Eq. 5.  `values` must be ordered
/// most-recent-first; the returned weights align with that order.
pub fn decay_weights(n: usize, delta: f64) -> Vec<f64> {
    (0..n).map(|i| delta.powi(i as i32)).collect()
}

/// Pearson correlation coefficient r; returns None if either side is
/// degenerate (zero variance) or lengths mismatch/empty.
pub fn pearson(xs: &[f64], ys: &[f64]) -> Option<f64> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return None;
    }
    Some(sxy / (sxx * syy).sqrt())
}

/// p-quantile (0..=1) by linear interpolation over a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// p-quantile (0..=1) by linear interpolation over an already
/// ascending-sorted slice — lets callers computing several quantiles of the
/// same data sort once instead of once per quantile (see
/// [`crate::engine::metrics::EngineMetrics::snapshot`]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = idx - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// An empty accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Running population variance (0.0 for fewer than 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Running population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Combine with another accumulator (Chan et al.'s parallel update) —
    /// used by the engine router to aggregate per-replica metrics.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Smallest observation (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn weighted_mean_matches_unweighted_for_equal_weights() {
        let xs = [2.0, 4.0, 9.0];
        let w = [1.0, 1.0, 1.0];
        assert!((weighted_mean(&xs, &w) - mean(&xs)).abs() < 1e-12);
        assert!((weighted_variance(&xs, &w) - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn weighted_variance_emphasizes_recent() {
        // values most-recent-first; a recent outlier dominates under decay
        let recent_spike = [10.0, 1.0, 1.0, 1.0, 1.0];
        let old_spike = [1.0, 1.0, 1.0, 1.0, 10.0];
        let w = decay_weights(5, 0.5);
        assert!(
            weighted_variance(&recent_spike, &w) > weighted_variance(&old_spike, &w)
        );
    }

    #[test]
    fn decay_weights_match_eq5() {
        let w = decay_weights(4, 0.85);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 0.85).abs() < 1e-12);
        assert!((w[3] - 0.85f64.powi(3)).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys).unwrap() - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0];
        assert!((pearson(&xs, &neg).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_none() {
        assert!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]).is_none());
        assert!(pearson(&[1.0], &[1.0]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn welford_merge_matches_single_pass() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0, 3.0];
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let (a, b) = xs.split_at(4);
        let mut wa = Welford::new();
        for &x in a {
            wa.push(x);
        }
        let mut wb = Welford::new();
        for &x in b {
            wb.push(x);
        }
        wa.merge(&wb);
        assert_eq!(wa.count(), whole.count());
        assert!((wa.mean() - whole.mean()).abs() < 1e-12);
        assert!((wa.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(wa.min(), whole.min());
        assert_eq!(wa.max(), whole.max());
    }

    #[test]
    fn welford_merge_with_empty_is_identity() {
        let mut w = Welford::new();
        w.push(2.0);
        w.push(4.0);
        let before = (w.count(), w.mean(), w.variance());
        w.merge(&Welford::new());
        assert_eq!((w.count(), w.mean(), w.variance()), before);
        let mut empty = Welford::new();
        empty.merge(&w);
        assert_eq!(empty.count(), 2);
        assert!((empty.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }
}
