//! Refcounted frame buffers, a recycling pool, and the vectored
//! per-connection output queue — the zero-copy streaming datapath.
//!
//! The event-loop front-end used to copy every preformatted NDJSON frame
//! from its SPSC ring into a contiguous per-connection `outbuf`
//! (`extend_from_slice`), then periodically compact that buffer.  At
//! 100k streams those memcpys and the per-frame allocations inside the
//! encoders dominate the hot path.  This module removes both:
//!
//! * [`Frame`] (`Arc<FrameBuf>`) — one encoded frame, shared by
//!   reference.  The replica thread encodes it once; every queue it
//!   lands in afterwards holds a refcount, never a copy.
//! * [`BufPool`] — a bounded free-list of `Vec<u8>` backing stores.
//!   Dropping the last `Frame` handle returns its allocation to the pool
//!   (cross-thread: the pool handle inside the frame is a `Weak`, so a
//!   frame outliving its pool simply frees).  Hit/miss counters are
//!   shared `AtomicU64`s so `FrontendStats` can export them.
//! * [`FrameQueue`] — the per-connection output queue: a deque of
//!   `(Frame, cursor)` segments flushed with `writev(2)`, batching up to
//!   [`IOV_MAX`] iovecs per syscall.  Nothing is ever copied or
//!   compacted; a fully written segment is popped (dropping its
//!   refcount, which recycles the buffer).
//!
//! Steady-state streaming therefore performs **zero allocations per
//! frame** once the pool is warm — pinned by the counting-allocator
//! section of `benches/serving_load.rs`.

use std::collections::VecDeque;
use std::io;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::util::sys::{writev, IoVec, IOV_MAX};

/// Shared state behind a [`BufPool`] and the `Weak` handles inside
/// pooled frames.
#[derive(Debug)]
struct PoolInner {
    free: Mutex<Vec<Vec<u8>>>,
    max_free: usize,
    hits: Arc<AtomicU64>,
    misses: Arc<AtomicU64>,
}

/// Bounded recycling pool of `Vec<u8>` frame backings.
///
/// Clones share the same free list, so one pool handle per replica plus
/// one inside every in-flight [`Frame`] is the normal shape.
#[derive(Clone, Debug)]
pub struct BufPool {
    inner: Arc<PoolInner>,
}

impl BufPool {
    /// Pool holding at most `max_free` idle buffers, with private
    /// hit/miss counters (see [`BufPool::with_counters`] to share them
    /// with a metrics exporter).
    pub fn new(max_free: usize) -> BufPool {
        BufPool::with_counters(
            max_free,
            Arc::new(AtomicU64::new(0)),
            Arc::new(AtomicU64::new(0)),
        )
    }

    /// Pool whose hit/miss counters are the caller's atomics (shared with
    /// `FrontendStats` so `/v1/metrics` sees them without polling the
    /// pool).
    pub fn with_counters(
        max_free: usize,
        hits: Arc<AtomicU64>,
        misses: Arc<AtomicU64>,
    ) -> BufPool {
        BufPool {
            inner: Arc::new(PoolInner {
                free: Mutex::new(Vec::new()),
                max_free,
                hits,
                misses,
            }),
        }
    }

    /// Take an empty buffer: recycled when the free list has one (hit),
    /// freshly allocated otherwise (miss).
    pub fn take(&self) -> Vec<u8> {
        let recycled = self.inner.free.lock().expect("bufpool poisoned").pop();
        match recycled {
            Some(mut buf) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                buf
            }
            None => {
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(256)
            }
        }
    }

    /// Seal an encoded buffer into a shared [`Frame`] that returns its
    /// allocation to this pool when the last handle drops.
    pub fn seal(&self, buf: Vec<u8>) -> Frame {
        Arc::new(FrameBuf {
            buf,
            pool: Some(Arc::downgrade(&self.inner)),
        })
    }

    /// Pool hits so far.
    pub fn hits(&self) -> u64 {
        self.inner.hits.load(Ordering::Relaxed)
    }

    /// Pool misses so far.
    pub fn misses(&self) -> u64 {
        self.inner.misses.load(Ordering::Relaxed)
    }

    /// Idle buffers currently parked in the free list.
    pub fn idle(&self) -> usize {
        self.inner.free.lock().expect("bufpool poisoned").len()
    }
}

/// One encoded frame: immutable bytes plus an optional way home.
///
/// Always handled as [`Frame`] (`Arc<FrameBuf>`); derefs to `[u8]`.
#[derive(Debug)]
pub struct FrameBuf {
    buf: Vec<u8>,
    pool: Option<Weak<PoolInner>>,
}

/// A shared, immutable, refcounted encoded frame.
pub type Frame = Arc<FrameBuf>;

impl FrameBuf {
    /// Wrap plain bytes with no pool affiliation (immediate responses,
    /// abort frames, one-off payloads — dropped normally).
    pub fn unpooled(buf: Vec<u8>) -> Frame {
        Arc::new(FrameBuf { buf, pool: None })
    }
}

impl Deref for FrameBuf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.buf
    }
}

impl Drop for FrameBuf {
    fn drop(&mut self) {
        let Some(pool) = self.pool.take().and_then(|w| w.upgrade()) else {
            return;
        };
        let mut free = pool.free.lock().expect("bufpool poisoned");
        if free.len() < pool.max_free {
            free.push(std::mem::take(&mut self.buf));
        }
    }
}

/// One queued segment: a shared frame and how much of it is written.
#[derive(Debug)]
struct Segment {
    frame: Frame,
    pos: usize,
}

/// Byte counts and syscall bookkeeping from one [`FrameQueue`] flush.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlushResult {
    /// Bytes the kernel accepted.
    pub written: usize,
    /// `writev(2)` calls issued.
    pub syscalls: u64,
    /// The socket buffer filled before the queue emptied (`EAGAIN`).
    pub blocked: bool,
}

/// Per-connection output queue of refcounted frames with an offset
/// cursor per segment, flushed via vectored writes.
///
/// Backpressure accounting is by *queued bytes*
/// ([`FrameQueue::queued`]), which is exactly what the old contiguous
/// `outbuf.len() - out_pos` measured — slow-reader semantics carry over
/// unchanged.
#[derive(Debug, Default)]
pub struct FrameQueue {
    segs: VecDeque<Segment>,
    queued: usize,
}

impl FrameQueue {
    /// An empty queue.
    pub fn new() -> FrameQueue {
        FrameQueue::default()
    }

    /// Enqueue a frame by reference (refcount bump, no copy).  Empty
    /// frames are dropped on the floor.
    pub fn push(&mut self, frame: Frame) {
        if frame.is_empty() {
            return;
        }
        self.queued += frame.len();
        self.segs.push_back(Segment { frame, pos: 0 });
    }

    /// Unwritten bytes across all segments.
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Queued segment count (each flush batches up to [`IOV_MAX`] of
    /// these per syscall).
    pub fn segments(&self) -> usize {
        self.segs.len()
    }

    /// Drop everything unwritten (connection teardown).
    pub fn clear(&mut self) {
        self.segs.clear();
        self.queued = 0;
    }

    /// Consume `n` written bytes from the front: advances the first
    /// segment's cursor and pops segments as they complete.  Public so
    /// short-write handling is unit-testable without a socket.
    ///
    /// # Panics
    /// Panics if `n` exceeds [`FrameQueue::queued`] — the kernel never
    /// reports writing more than it was given.
    pub fn advance(&mut self, mut n: usize) {
        assert!(n <= self.queued, "advance past end of queue");
        self.queued -= n;
        while n > 0 {
            let seg = self.segs.front_mut().expect("queued bytes imply a segment");
            let left = seg.frame.len() - seg.pos;
            if n < left {
                seg.pos += n;
                return;
            }
            n -= left;
            self.segs.pop_front();
        }
    }

    /// Append up to `max` pending bytes into `scratch` without consuming
    /// them — the copying flush used for the writev-vs-copy bench A/B
    /// (call [`FrameQueue::advance`] with what actually got written).
    pub fn fill_copy(&self, scratch: &mut Vec<u8>, max: usize) {
        let mut left = max;
        for seg in &self.segs {
            if left == 0 {
                break;
            }
            let bytes = &seg.frame[seg.pos..];
            let take = bytes.len().min(left);
            scratch.extend_from_slice(&bytes[..take]);
            left -= take;
        }
    }

    /// Flush as much as the socket accepts: gathers up to [`IOV_MAX`]
    /// segments per `writev(2)`, loops until the queue empties or the
    /// kernel reports `WouldBlock` (reported in
    /// [`FlushResult::blocked`], not as an error).
    pub fn flush_fd(&mut self, fd: i32) -> io::Result<FlushResult> {
        let mut res = FlushResult::default();
        while !self.is_empty() {
            let mut iov = [IoVec {
                base: std::ptr::null(),
                len: 0,
            }; IOV_MAX];
            let mut n = 0;
            for seg in &self.segs {
                if n == IOV_MAX {
                    break;
                }
                iov[n] = IoVec::from_slice(&seg.frame[seg.pos..]);
                n += 1;
            }
            match writev(fd, &iov[..n]) {
                Ok(0) => {
                    res.blocked = true;
                    return Ok(res);
                }
                Ok(written) => {
                    res.syscalls += 1;
                    res.written += written;
                    self.advance(written);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    res.blocked = true;
                    return Ok(res);
                }
                Err(e) => return Err(e),
            }
        }
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn frame(bytes: &[u8]) -> Frame {
        FrameBuf::unpooled(bytes.to_vec())
    }

    #[test]
    fn pool_recycles_dropped_frames() {
        let pool = BufPool::new(8);
        let mut buf = pool.take();
        assert_eq!(pool.misses(), 1);
        buf.extend_from_slice(b"hello");
        let cap = buf.capacity();
        let f = pool.seal(buf);
        assert_eq!(&f[..], b"hello");
        drop(f);
        assert_eq!(pool.idle(), 1);
        let again = pool.take();
        assert_eq!(pool.hits(), 1);
        assert!(again.is_empty(), "recycled buffer must come back cleared");
        assert_eq!(again.capacity(), cap, "recycled, not reallocated");
    }

    #[test]
    fn pool_free_list_is_bounded() {
        let pool = BufPool::new(2);
        let frames: Vec<Frame> = (0..5).map(|_| pool.seal(pool.take())).collect();
        drop(frames);
        assert_eq!(pool.idle(), 2, "free list capped at max_free");
    }

    #[test]
    fn frame_outliving_pool_frees_without_panic() {
        let pool = BufPool::new(8);
        let f = pool.seal(pool.take());
        drop(pool);
        drop(f); // Weak upgrade fails; the Vec just frees
    }

    #[test]
    fn queue_tracks_bytes_and_segments() {
        let mut q = FrameQueue::new();
        assert!(q.is_empty());
        q.push(frame(b"abc"));
        q.push(frame(b"")); // empty frames are ignored
        q.push(frame(b"defg"));
        assert_eq!(q.queued(), 7);
        assert_eq!(q.segments(), 2);
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.segments(), 0);
    }

    #[test]
    fn advance_handles_short_writes_across_segment_boundaries() {
        let mut q = FrameQueue::new();
        q.push(frame(b"aaaa"));
        q.push(frame(b"bb"));
        q.push(frame(b"cccccc"));
        // short write inside the first segment
        q.advance(2);
        assert_eq!(q.queued(), 10);
        assert_eq!(q.segments(), 3);
        // exactly finishes the first, swallows the second, lands mid-third
        q.advance(2 + 2 + 1);
        assert_eq!(q.queued(), 5);
        assert_eq!(q.segments(), 1);
        // write landing exactly on a segment boundary pops it
        q.advance(5);
        assert!(q.is_empty());
        assert_eq!(q.segments(), 0);
    }

    #[test]
    #[should_panic(expected = "advance past end")]
    fn advance_past_queued_bytes_panics() {
        let mut q = FrameQueue::new();
        q.push(frame(b"xy"));
        q.advance(3);
    }

    #[test]
    fn fill_copy_respects_cursor_and_cap() {
        let mut q = FrameQueue::new();
        q.push(frame(b"abcd"));
        q.push(frame(b"efgh"));
        q.advance(2);
        let mut scratch = Vec::new();
        q.fill_copy(&mut scratch, 5);
        assert_eq!(&scratch, b"cdefg");
        scratch.clear();
        q.fill_copy(&mut scratch, 100);
        assert_eq!(&scratch, b"cdefgh");
        assert_eq!(q.queued(), 6, "fill_copy must not consume");
    }

    #[test]
    fn flush_fd_writes_all_segments_in_order() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let mut q = FrameQueue::new();
        q.push(frame(b"one,"));
        q.push(frame(b"two,"));
        q.push(frame(b"three"));
        let res = q.flush_fd(tx.as_raw_fd()).unwrap();
        assert_eq!(res.written, 13);
        assert!(res.syscalls >= 1);
        assert!(!res.blocked);
        assert!(q.is_empty());
        let mut got = vec![0u8; 13];
        rx.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"one,two,three");
    }

    #[test]
    fn flush_fd_reports_blocked_and_resumes_where_it_left_off() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        tx.set_nonblocking(true).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let payload = vec![0x5au8; 256 * 1024];
        let mut q = FrameQueue::new();
        for chunk in payload.chunks(4096) {
            q.push(frame(chunk));
        }
        let mut sent = 0;
        let first = q.flush_fd(tx.as_raw_fd()).unwrap();
        sent += first.written;
        assert!(first.blocked, "256KiB must overrun an unread socket buffer");
        assert!(!q.is_empty());
        // drain the reader side, then keep flushing until done
        let mut got = Vec::new();
        while sent < payload.len() || got.len() < payload.len() {
            let mut buf = [0u8; 65536];
            match rx.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => got.extend_from_slice(&buf[..n]),
                Err(e) => panic!("reader failed: {e}"),
            }
            let r = q.flush_fd(tx.as_raw_fd()).unwrap();
            sent += r.written;
        }
        assert_eq!(sent, payload.len());
        assert_eq!(got, payload);
        assert!(q.is_empty());
    }

    #[test]
    fn flush_batches_more_than_iov_max_segments() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let tx = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (mut rx, _) = l.accept().unwrap();
        let mut q = FrameQueue::new();
        let n = IOV_MAX + 37;
        for _ in 0..n {
            q.push(frame(b"x"));
        }
        let reader = std::thread::spawn(move || {
            let mut got = Vec::new();
            let mut buf = [0u8; 4096];
            while got.len() < n {
                let k = rx.read(&mut buf).unwrap();
                if k == 0 {
                    break;
                }
                got.extend_from_slice(&buf[..k]);
            }
            got
        });
        let res = q.flush_fd(tx.as_raw_fd()).unwrap();
        assert_eq!(res.written, n);
        assert!(res.syscalls >= 2, "must loop past IOV_MAX in batches");
        drop(tx);
        let got = reader.join().unwrap();
        assert_eq!(got.len(), n);
        assert!(got.iter().all(|&b| b == b'x'));
    }
}
