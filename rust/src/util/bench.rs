//! Micro-benchmark harness (no `criterion` offline): warmup, timed
//! iterations, mean/p50/p99 reporting, and a simple table printer used by
//! the paper-reproduction bench binaries to emit rows matching the paper's
//! tables.

use std::time::Instant;

use crate::util::stats::percentile;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label, as passed to [`bench`] / [`summarize`].
    pub name: String,
    /// Timed iterations the statistics cover.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 99th-percentile seconds per iteration.
    pub p99_s: f64,
    /// Fastest iteration in seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// One human-readable report line (name + mean/p50/p99 + iters).
    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>12} mean  {:>12} p50  {:>12} p99  ({} iters)",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p99_s),
            self.iters
        )
    }
}

/// Human-scale duration formatting (s / ms / µs / ns).
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Summarize externally collected per-iteration timings.
pub fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: percentile(samples, 0.5),
        p99_s: percentile(samples, 0.99),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; panics if the cell count mismatches the headers.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render the table with aligned columns (markdown-ish pipes).
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let r = bench("noop-spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p99_s >= r.p50_s || (r.p99_s - r.p50_s).abs() < 1e-9);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn summarize_percentiles() {
        let r = summarize("x", &[1.0, 2.0, 3.0, 4.0]);
        assert!((r.mean_s - 2.5).abs() < 1e-12);
        assert!((r.p50_s - 2.5).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Latency"]);
        t.row(&["Autoregressive".into(), "38.41".into()]);
        t.row(&["DSDE".into(), "13.97".into()]);
        let s = t.render();
        assert!(s.contains("| Method"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["x".into(), "y".into()]);
    }
}
