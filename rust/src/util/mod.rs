//! Foundational substrates built in-crate (the offline vendor set has no
//! `rand`, `serde`, `clap`, `criterion`, or `proptest` — so we provide the
//! pieces the rest of the stack needs ourselves).

pub mod bench;
pub mod bufpool;
pub mod cli;
pub mod fault;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod ring;
pub mod rng;
pub mod spsc;
pub mod stats;
pub mod sys;
pub mod timerwheel;
