//! Bounded lock-free single-producer/single-consumer ring.
//!
//! The event-loop front-end's token delivery path uses one of these per
//! (engine replica, loop shard) pair: the replica thread is the sole
//! producer, the shard's event loop the sole consumer, so a classic
//! two-index ring with release/acquire publication is enough — no locks,
//! no CAS loops, no per-item allocation (slots are storage inline in the
//! ring).  The existing [`crate::util::ring`] buffers are single-threaded
//! retention windows and deliberately stay that way; this module is the
//! concurrent queue.
//!
//! Semantics the serving layer depends on:
//!
//! * **Bounded, full ⇒ backpressure, never drop.**  [`Producer::try_push`]
//!   hands the value back on a full ring; callers either retry (pushing
//!   back on the producing engine) or queue it themselves.  Nothing is
//!   silently discarded.
//! * **Close detection both ways.**  Dropping either endpoint marks the
//!   ring closed: a producer learns its consumer is gone (stop producing),
//!   a consumer drains what remains and then sees
//!   [`Consumer::is_closed`].
//! * **Depth watermarking.**  [`Consumer::len`] is exact enough for
//!   high-water tracking (`/v1/metrics` reports the max observed ring
//!   depth).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pad hot atomics to a cache line so the producer and consumer indices
/// do not false-share.
#[repr(align(64))]
struct CachePadded<T>(T);

struct Shared<T> {
    mask: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Next slot the consumer pops (monotonic, wraps via `mask`).
    head: CachePadded<AtomicUsize>,
    /// Next slot the producer fills (monotonic, wraps via `mask`).
    tail: CachePadded<AtomicUsize>,
    producer_alive: AtomicBool,
    consumer_alive: AtomicBool,
}

// SAFETY: the ring transfers `T` by value between exactly two threads;
// slot access is synchronised by the head/tail release/acquire pair, so
// `T: Send` is the only requirement.
unsafe impl<T: Send> Send for Shared<T> {}
// SAFETY: see above — &Shared is only ever used through the single
// Producer and single Consumer endpoint, each confined to one thread.
unsafe impl<T: Send> Sync for Shared<T> {}

impl<T> Drop for Shared<T> {
    fn drop(&mut self) {
        // Sole owner at this point: drop every still-initialised slot in
        // [head, tail).
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Relaxed);
        for i in head..tail {
            let slot = self.slots[i & self.mask].get();
            // SAFETY: slots in [head, tail) were written by a push and
            // never popped; we have exclusive access in Drop.
            unsafe { (*slot).assume_init_drop() };
        }
    }
}

/// Error returned by [`Producer::try_push`], handing the value back.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The ring is full; retry after the consumer drains (backpressure).
    Full(T),
    /// The consumer is gone; the value can never be delivered.
    Closed(T),
}

/// The producing endpoint (exactly one; `!Clone`).
pub struct Producer<T> {
    shared: Arc<Shared<T>>,
}

/// The consuming endpoint (exactly one; `!Clone`).
pub struct Consumer<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded SPSC ring holding at least `capacity` items
/// (rounded up to a power of two, minimum 2).
pub fn ring<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let cap = capacity.max(2).next_power_of_two();
    let slots = (0..cap)
        .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
        .collect::<Vec<_>>()
        .into_boxed_slice();
    let shared = Arc::new(Shared {
        mask: cap - 1,
        slots,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        producer_alive: AtomicBool::new(true),
        consumer_alive: AtomicBool::new(true),
    });
    (
        Producer {
            shared: shared.clone(),
        },
        Consumer { shared },
    )
}

impl<T: Send> Producer<T> {
    /// Push without blocking.  On a full ring or a dropped consumer the
    /// value comes back in the error so the caller can apply
    /// backpressure or dispose of it — it is never dropped silently.
    pub fn try_push(&mut self, value: T) -> Result<(), PushError<T>> {
        let s = &*self.shared;
        if !s.consumer_alive.load(Ordering::Acquire) {
            return Err(PushError::Closed(value));
        }
        let tail = s.tail.0.load(Ordering::Relaxed);
        let head = s.head.0.load(Ordering::Acquire);
        if tail - head > s.mask {
            return Err(PushError::Full(value));
        }
        let slot = s.slots[tail & s.mask].get();
        // SAFETY: slot `tail` is outside [head, tail) — the consumer will
        // not touch it until the tail store below publishes it.
        unsafe { (*slot).write(value) };
        s.tail.0.store(tail + 1, Ordering::Release);
        Ok(())
    }

    /// Items currently queued (producer-side view).
    pub fn len(&self) -> usize {
        let s = &*self.shared;
        s.tail.0.load(Ordering::Relaxed) - s.head.0.load(Ordering::Acquire)
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the consumer endpoint has been dropped (pushes can never
    /// be delivered).
    pub fn is_closed(&self) -> bool {
        !self.shared.consumer_alive.load(Ordering::Acquire)
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.shared.producer_alive.store(false, Ordering::Release);
    }
}

impl<T: Send> Consumer<T> {
    /// Pop the oldest item, or `None` when the ring is currently empty.
    pub fn try_pop(&mut self) -> Option<T> {
        let s = &*self.shared;
        let head = s.head.0.load(Ordering::Relaxed);
        let tail = s.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let slot = s.slots[head & s.mask].get();
        // SAFETY: slot `head` is inside [head, tail): published by the
        // producer's release store and not yet consumed.
        let value = unsafe { (*slot).assume_init_read() };
        s.head.0.store(head + 1, Ordering::Release);
        Some(value)
    }

    /// Items currently queued (consumer-side view; exact for watermarks).
    pub fn len(&self) -> usize {
        let s = &*self.shared;
        s.tail.0.load(Ordering::Acquire) - s.head.0.load(Ordering::Relaxed)
    }

    /// Whether nothing is queued right now.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the producer endpoint has been dropped *and* everything it
    /// pushed has been drained — i.e. no item will ever arrive again.
    pub fn is_closed(&self) -> bool {
        // order matters: check producer liveness before emptiness so a
        // producer that pushes-then-drops is never reported closed while
        // its last items are still queued
        !self.shared.producer_alive.load(Ordering::Acquire) && self.is_empty()
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.shared.consumer_alive.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_capacity() {
        let (mut tx, mut rx) = ring::<u32>(4);
        for i in 0..4 {
            tx.try_push(i).unwrap();
        }
        assert_eq!(rx.len(), 4);
        for i in 0..4 {
            assert_eq!(rx.try_pop(), Some(i));
        }
        assert_eq!(rx.try_pop(), None);
    }

    #[test]
    fn wraparound_preserves_order() {
        let (mut tx, mut rx) = ring::<u64>(4);
        // push/pop far past capacity so indices wrap many times
        let mut next_expected = 0u64;
        let mut next_pushed = 0u64;
        for round in 0..1000 {
            let burst = 1 + (round % 4);
            for _ in 0..burst {
                tx.try_push(next_pushed).unwrap();
                next_pushed += 1;
            }
            for _ in 0..burst {
                assert_eq!(rx.try_pop(), Some(next_expected));
                next_expected += 1;
            }
        }
        assert!(rx.is_empty());
    }

    #[test]
    fn full_ring_backpressures_instead_of_dropping() {
        let (mut tx, mut rx) = ring::<u32>(2);
        tx.try_push(1).unwrap();
        tx.try_push(2).unwrap();
        // capacity 2: the third push must hand the value back intact
        assert_eq!(tx.try_push(3), Err(PushError::Full(3)));
        assert_eq!(tx.len(), 2);
        // one pop frees exactly one slot
        assert_eq!(rx.try_pop(), Some(1));
        tx.try_push(3).unwrap();
        assert_eq!(tx.try_push(4), Err(PushError::Full(4)));
        assert_eq!(rx.try_pop(), Some(2));
        assert_eq!(rx.try_pop(), Some(3));
        assert_eq!(rx.try_pop(), None);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        let (mut tx, _rx) = ring::<u8>(5);
        for i in 0..8 {
            tx.try_push(i).unwrap(); // 5 rounds up to 8
        }
        assert!(matches!(tx.try_push(9), Err(PushError::Full(9))));
    }

    #[test]
    fn consumer_drop_closes_producer_side() {
        let (mut tx, rx) = ring::<u32>(4);
        tx.try_push(1).unwrap();
        drop(rx);
        assert!(tx.is_closed());
        assert_eq!(tx.try_push(2), Err(PushError::Closed(2)));
    }

    #[test]
    fn producer_drop_lets_consumer_drain_then_reports_closed() {
        let (mut tx, mut rx) = ring::<u32>(4);
        tx.try_push(1).unwrap();
        tx.try_push(2).unwrap();
        drop(tx);
        // items pushed before the drop must still drain
        assert!(!rx.is_closed());
        assert_eq!(rx.try_pop(), Some(1));
        assert_eq!(rx.try_pop(), Some(2));
        assert!(rx.is_closed());
        assert_eq!(rx.try_pop(), None);
    }

    #[test]
    fn dropped_ring_drops_undelivered_items() {
        // leak-check via Arc strong counts observed through Weak
        let tracker = Arc::new(());
        let (mut tx, rx) = ring::<Arc<()>>(8);
        for _ in 0..5 {
            tx.try_push(tracker.clone()).unwrap();
        }
        assert_eq!(Arc::strong_count(&tracker), 6);
        drop(tx);
        drop(rx);
        assert_eq!(Arc::strong_count(&tracker), 1, "queued items leaked");
    }

    #[test]
    fn cross_thread_ordering_under_contention() {
        // property: whatever interleaving the scheduler produces, the
        // consumer sees exactly 0..N in order, with pushes backpressured
        // through a deliberately tiny ring
        const N: u64 = 200_000;
        let (mut tx, mut rx) = ring::<u64>(8);
        let producer = std::thread::spawn(move || {
            let mut v = 0u64;
            while v < N {
                match tx.try_push(v) {
                    Ok(()) => v += 1,
                    Err(PushError::Full(_)) => std::thread::yield_now(),
                    Err(PushError::Closed(_)) => panic!("consumer vanished"),
                }
            }
        });
        let mut expected = 0u64;
        while expected < N {
            match rx.try_pop() {
                Some(v) => {
                    assert_eq!(v, expected, "reordered or duplicated item");
                    expected += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        assert_eq!(rx.try_pop(), None);
        producer.join().unwrap();
        assert!(rx.is_closed());
    }

    #[test]
    fn cross_thread_depth_never_exceeds_capacity() {
        const N: u64 = 50_000;
        let (mut tx, mut rx) = ring::<u64>(16);
        let producer = std::thread::spawn(move || {
            let mut v = 0u64;
            while v < N {
                if tx.try_push(v).is_ok() {
                    v += 1;
                } else {
                    std::thread::yield_now();
                }
            }
        });
        let mut popped = 0u64;
        let mut max_depth = 0usize;
        while popped < N {
            max_depth = max_depth.max(rx.len());
            if rx.try_pop().is_some() {
                popped += 1;
            }
        }
        producer.join().unwrap();
        assert!(max_depth <= 16, "depth {max_depth} exceeded capacity");
    }
}
