//! Coarse hashed timer wheel for connection deadlines.
//!
//! The event-loop front-end used to sweep every open connection once per
//! tick to test three deadlines (header read, idle, write stall).  At 100k
//! mostly-idle streams that sweep dominates the tick.  This wheel makes
//! deadline checks O(due) instead of O(open): each connection keeps one
//! armed entry, [`TimerWheel::advance`] visits only the slots whose tick
//! has arrived, and the loop re-arms a fired entry against the
//! connection's *actual* deadline (which may have moved later since the
//! entry was scheduled — deadlines only ever extend with progress).
//!
//! Guarantees, pinned by property tests below:
//!
//! * **Never early** — a key is emitted only once `now >= due`.
//! * **At most one tick late** — driven at tick granularity, a key due at
//!   `D` is emitted by the first `advance(now)` with `now >= D`, and that
//!   call happens before `D + 2·tick`.
//!
//! Far-future entries land in their natural slot and get re-bucketed
//! ("cascade") each wheel revolution until their tick arrives; near-due
//! entries whose slot fires just before their exact deadline re-bucket
//! into the next tick.  Cascade counts are exported for `/v1/metrics`
//! (`timer_wheel_cascades`) so operators can see when the wheel horizon
//! is too small for the configured timeouts.

/// One armed deadline.
#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Absolute due time, in the caller's millisecond clock.
    due_ms: u64,
    /// Caller cookie (the event loop uses connection tokens).
    key: u64,
}

/// Hashed timer wheel: `slots` buckets of `tick_ms` width each.
#[derive(Debug)]
pub struct TimerWheel {
    tick_ms: u64,
    slots: Vec<Vec<Entry>>,
    /// Tick index advance has fully processed (slot `now_tick % slots`
    /// holds entries for the *next* revolution).
    now_tick: u64,
    cascades: u64,
    len: usize,
    scratch: Vec<Entry>,
}

impl TimerWheel {
    /// Create a wheel with `slots` buckets of `tick_ms` milliseconds.
    /// The horizon (one revolution) is `tick_ms * slots`; entries beyond
    /// it cascade, which is correct but costs a re-bucket per revolution.
    ///
    /// # Panics
    /// Panics if `tick_ms` or `slots` is zero.
    pub fn new(tick_ms: u64, slots: usize) -> TimerWheel {
        assert!(tick_ms > 0, "tick must be nonzero");
        assert!(slots > 0, "wheel needs at least one slot");
        TimerWheel {
            tick_ms,
            slots: (0..slots).map(|_| Vec::new()).collect(),
            now_tick: 0,
            cascades: 0,
            len: 0,
            scratch: Vec::new(),
        }
    }

    /// Arm `key` to fire once `now >= due_ms`.  Multiple entries may share
    /// a key (the caller filters stale fires); already-past deadlines fire
    /// on the next [`advance`](TimerWheel::advance).
    pub fn schedule(&mut self, due_ms: u64, key: u64) {
        let natural = due_ms / self.tick_ms;
        // a slot at or behind `now_tick` is not visited again until the
        // wheel wraps — clamp past-due entries onto the next tick
        let tick = natural.max(self.now_tick + 1);
        let slot = (tick % self.slots.len() as u64) as usize;
        self.slots[slot].push(Entry { due_ms, key });
        self.len += 1;
    }

    /// Advance the wheel to `now_ms`, appending every due key to `out`
    /// (cleared first).  Visits at most `min(elapsed_ticks, slots)`
    /// buckets; entries seen before their due time are re-bucketed and
    /// counted as cascades.
    pub fn advance(&mut self, now_ms: u64, out: &mut Vec<u64>) {
        out.clear();
        let target = now_ms / self.tick_ms;
        if target <= self.now_tick {
            return;
        }
        let nslots = self.slots.len() as u64;
        let steps = (target - self.now_tick).min(nslots);
        for i in 1..=steps {
            let tick = self.now_tick + i;
            let slot = (tick % nslots) as usize;
            self.scratch.append(&mut self.slots[slot]);
            while let Some(e) = self.scratch.pop() {
                if e.due_ms <= now_ms {
                    self.len -= 1;
                    out.push(e.key);
                    continue;
                }
                // not due yet: its natural tick is still ahead (or it is
                // due within a not-yet-elapsed fraction of this tick) —
                // re-bucket so it is examined exactly when due
                self.cascades += 1;
                let natural = e.due_ms / self.tick_ms;
                let retick = natural.max(tick + 1);
                let reslot = (retick % nslots) as usize;
                self.slots[reslot].push(e);
            }
        }
        self.now_tick = target;
    }

    /// Number of armed entries (including stale duplicates).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no entries are armed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total re-buckets so far (monotonic; read-and-report for metrics).
    pub fn cascades(&self) -> u64 {
        self.cascades
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, forall};

    #[test]
    fn fires_once_due_and_not_before() {
        let mut w = TimerWheel::new(10, 8);
        w.schedule(35, 1);
        let mut out = Vec::new();
        w.advance(30, &mut out);
        assert!(out.is_empty(), "fired {}ms early", 35 - 30);
        w.advance(40, &mut out);
        assert_eq!(out, vec![1]);
        assert!(w.is_empty());
    }

    #[test]
    fn past_due_entry_fires_on_next_advance() {
        let mut w = TimerWheel::new(10, 8);
        let mut out = Vec::new();
        w.advance(500, &mut out);
        w.schedule(100, 7); // already long past
        w.advance(510, &mut out);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn far_future_entries_cascade_and_still_fire_on_time() {
        // horizon is 8 ticks * 10ms = 80ms; schedule 10 revolutions out
        let mut w = TimerWheel::new(10, 8);
        w.schedule(805, 3);
        let mut out = Vec::new();
        let mut t = 0;
        let mut fired_at = None;
        while t < 900 {
            t += 10;
            w.advance(t, &mut out);
            if !out.is_empty() {
                assert_eq!(out, vec![3]);
                fired_at = Some(t);
                break;
            }
        }
        let fired_at = fired_at.expect("entry never fired");
        assert!(fired_at >= 805, "fired early at {fired_at}");
        assert!(fired_at < 805 + 20, "fired late at {fired_at}");
        assert!(w.cascades() > 0, "a 10-revolution entry must cascade");
    }

    #[test]
    fn large_time_jump_fires_everything_due() {
        let mut w = TimerWheel::new(10, 8);
        for k in 0..100u64 {
            w.schedule(k * 7, k);
        }
        let mut out = Vec::new();
        w.advance(10_000, &mut out);
        let mut got = out.clone();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<u64>>());
        assert!(w.is_empty());
    }

    #[test]
    fn property_never_early_and_within_one_tick_of_due() {
        forall(
            0x7EE1,
            60,
            |r| {
                let tick = (r.range(1, 50) + 1) as u64;
                let slots = r.range(2, 32);
                let n = r.range(1, 40);
                let dues: Vec<u64> = (0..n).map(|_| r.range(0, 2000) as u64).collect();
                (tick, slots, dues)
            },
            |(tick, slots, dues)| {
                let mut w = TimerWheel::new(*tick, *slots);
                for (k, d) in dues.iter().enumerate() {
                    w.schedule(*d, k as u64);
                }
                let horizon = dues.iter().max().copied().unwrap_or(0) + 4 * tick;
                let mut fired: Vec<Option<u64>> = vec![None; dues.len()];
                let mut out = Vec::new();
                let mut now = 0;
                while now < horizon {
                    now += tick;
                    w.advance(now, &mut out);
                    for k in &out {
                        check(fired[*k as usize].is_none(), "key fired twice")?;
                        fired[*k as usize] = Some(now);
                    }
                }
                for (k, d) in dues.iter().enumerate() {
                    let at = fired[k].ok_or(format!("key {k} (due {d}) never fired"))?;
                    check(at >= *d, format!("key {k} fired at {at} before due {d}"))?;
                    check(
                        at < d + 2 * tick,
                        format!("key {k} due {d} fired at {at}, > one tick ({tick}ms) late"),
                    )?;
                }
                check(w.is_empty(), "entries left armed after horizon")?;
                Ok(())
            },
        );
    }

    #[test]
    fn property_irregular_advance_steps_never_fire_early() {
        forall(
            0xCA5CADE,
            40,
            |r| {
                let dues: Vec<u64> = (0..r.range(1, 20)).map(|_| r.range(0, 3000) as u64).collect();
                let steps: Vec<u64> = (0..60).map(|_| r.range(1, 200) as u64).collect();
                (dues, steps)
            },
            |(dues, steps)| {
                let mut w = TimerWheel::new(16, 8);
                for (k, d) in dues.iter().enumerate() {
                    w.schedule(*d, k as u64);
                }
                let mut out = Vec::new();
                let mut now = 0;
                for s in steps {
                    now += s;
                    w.advance(now, &mut out);
                    for k in &out {
                        check(
                            dues[*k as usize] <= now,
                            format!("key {k} due {} fired early at {now}", dues[*k as usize]),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
