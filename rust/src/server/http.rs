//! Minimal HTTP/1.1 server with a JSON completions API.
//!
//! Endpoints:
//! * `POST /v1/completions` — body `{"prompt": "...", "max_tokens": N,
//!   "temperature": T}` → `{"id": .., "text": .., "latency_s": ..,
//!   "ttft_s": .., "rounds": ..}` (blocks until the request completes).
//! * `GET /v1/metrics` — engine metrics snapshot.
//! * `GET /health` — liveness.
//!
//! One engine thread owns the [`Engine`]; connection threads submit work
//! through an mpsc channel and park on a per-request response channel.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::engine::Engine;
use crate::engine::request::{FinishedRequest, Request, SamplingParams};
use crate::model::vocab;
use crate::util::json::Json;
use crate::{log_info, log_warn};

/// A parsed HTTP request (the subset we serve).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one HTTP/1.1 request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Write an HTTP response with a JSON body.
pub fn write_json(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let body = body.to_string();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    Ok(())
}

enum EngineMsg {
    Submit(Request, Sender<FinishedRequest>),
    Metrics(Sender<Json>),
    Shutdown,
}

/// Handle used to submit work / stop the server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    tx: Sender<EngineMsg>,
    stop: Arc<AtomicBool>,
    engine_thread: Option<JoinHandle<()>>,
    acceptor_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(EngineMsg::Shutdown);
        // poke the acceptor so it notices the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.acceptor_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

/// The engine thread's loop: interleave request intake with engine steps so
/// new arrivals join the continuous batch.
fn engine_loop(mut engine: Engine, rx: Receiver<EngineMsg>, stop: Arc<AtomicBool>) {
    let mut pending: HashMap<u64, Sender<FinishedRequest>> = HashMap::new();
    let mut next_id: u64 = 1;
    loop {
        // drain the message queue (non-blocking while busy, blocking if idle)
        loop {
            let msg = if engine.pending() == 0 && pending.is_empty() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return,
                }
            };
            match msg {
                EngineMsg::Submit(mut req, reply) => {
                    req.id = next_id;
                    next_id += 1;
                    pending.insert(req.id, reply);
                    engine.submit(req);
                }
                EngineMsg::Metrics(reply) => {
                    let _ = reply.send(engine.metrics.to_json());
                }
                EngineMsg::Shutdown => {
                    engine.abort_all();
                    for fin in engine.take_finished() {
                        if let Some(reply) = pending.remove(&fin.id) {
                            let _ = reply.send(fin);
                        }
                    }
                    return;
                }
            }
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if engine.pending() > 0 {
            if let Err(e) = engine.step() {
                log_warn!("engine step error: {e:#}");
            }
            for fin in engine.take_finished() {
                if let Some(reply) = pending.remove(&fin.id) {
                    let _ = reply.send(fin);
                }
            }
        }
    }
}

fn handle_conn(mut stream: TcpStream, tx: &Sender<EngineMsg>) {
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(_) => return,
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let _ = write_json(&mut stream, 200, &Json::obj().set("ok", true));
        }
        ("GET", "/v1/metrics") => {
            let (rtx, rrx) = std::sync::mpsc::channel();
            if tx.send(EngineMsg::Metrics(rtx)).is_ok() {
                if let Ok(m) = rrx.recv() {
                    let _ = write_json(&mut stream, 200, &m);
                    return;
                }
            }
            let _ = write_json(&mut stream, 500, &Json::obj().set("error", "engine gone"));
        }
        ("POST", "/v1/completions") => {
            let parsed = match Json::parse(&req.body) {
                Ok(j) => j,
                Err(e) => {
                    let _ = write_json(
                        &mut stream,
                        400,
                        &Json::obj().set("error", format!("bad json: {e}")),
                    );
                    return;
                }
            };
            let Some(prompt) = parsed.get("prompt").and_then(|p| p.as_str()) else {
                let _ = write_json(
                    &mut stream,
                    400,
                    &Json::obj().set("error", "missing 'prompt'"),
                );
                return;
            };
            let max_tokens = parsed
                .get("max_tokens")
                .and_then(|x| x.as_usize())
                .unwrap_or(64);
            let temperature = parsed
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0);
            let request = Request::new(
                0, // engine thread assigns the real id
                vocab::encode(prompt),
                SamplingParams {
                    temperature,
                    max_tokens,
                    stop_token: None,
                },
            );
            let (rtx, rrx) = std::sync::mpsc::channel();
            if tx.send(EngineMsg::Submit(request, rtx)).is_err() {
                let _ = write_json(&mut stream, 500, &Json::obj().set("error", "engine gone"));
                return;
            }
            match rrx.recv() {
                Ok(fin) => {
                    let body = Json::obj()
                        .set("id", fin.id)
                        .set("text", fin.output_text())
                        .set("tokens", fin.output.len())
                        .set("latency_s", fin.latency())
                        .set("ttft_s", fin.ttft())
                        .set("rounds", fin.rounds)
                        .set("accepted", fin.accepted)
                        .set("drafted", fin.drafted);
                    let _ = write_json(&mut stream, 200, &body);
                }
                Err(_) => {
                    let _ =
                        write_json(&mut stream, 500, &Json::obj().set("error", "aborted"));
                }
            }
        }
        _ => {
            let _ = write_json(&mut stream, 404, &Json::obj().set("error", "not found"));
        }
    }
}

/// Start serving on `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
pub fn serve(engine: Engine, addr: &str) -> Result<ServerHandle> {
    static SERVER_SEQ: AtomicU64 = AtomicU64::new(0);
    let _ = SERVER_SEQ.fetch_add(1, Ordering::Relaxed);
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let (tx, rx) = std::sync::mpsc::channel();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_e = stop.clone();
    let engine_thread = std::thread::spawn(move || engine_loop(engine, rx, stop_e));
    let tx_acceptor = tx.clone();
    let stop_a = stop.clone();
    let acceptor_thread = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop_a.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let tx = tx_acceptor.clone();
                    std::thread::spawn(move || handle_conn(s, &tx));
                }
                Err(e) => log_warn!("accept error: {e}"),
            }
        }
    });
    log_info!("serving on http://{local}");
    Ok(ServerHandle {
        addr: local,
        tx,
        stop,
        engine_thread: Some(engine_thread),
        acceptor_thread: Some(acceptor_thread),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_server() -> ServerHandle {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            policy: SlPolicyKind::Dsde(Default::default()),
            seed: 1,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 1);
        serve(Engine::new(cfg, Box::new(model)), "127.0.0.1:0").unwrap()
    }

    fn raw_request(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_endpoint() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.contains("\"ok\":true"));
        h.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let h = sim_server();
        let body = r#"{"prompt": "def compute(x):", "max_tokens": 12}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"tokens\":12"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn metrics_endpoint() {
        let h = sim_server();
        let body = r#"{"prompt": "hi", "max_tokens": 4}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        raw_request(h.addr, &req);
        let resp = raw_request(
            h.addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("block_efficiency"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn bad_json_is_400() {
        let h = sim_server();
        let body = "{nope";
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn unknown_path_404() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 404"));
        h.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let h = sim_server();
        let addr = h.addr;
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let body =
                        format!(r#"{{"prompt": "req {i}", "max_tokens": 16}}"#);
                    let req = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                         Content-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    raw_request(addr, &req)
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        }
        h.shutdown();
    }
}
