//! HTTP/1.1 server with a JSON completions API, in two selectable
//! front-ends behind the same endpoints and byte-identical responses.
//!
//! Endpoints:
//! * `POST /v1/completions` — body `{"prompt": "...", "max_tokens": N,
//!   "temperature": T}` → `{"id": .., "text": .., "latency_s": ..,
//!   "ttft_s": .., "rounds": ..}` (blocks until the request completes).
//!   With `"stream": true` the response switches to HTTP/1.1 chunked
//!   transfer-encoding carrying newline-delimited JSON: one line per
//!   accepted-token delta (`{"text": .., "tokens": .., "t": ..}`) as the
//!   engine applies it, then a terminal line (`{"done": true,
//!   "finish_reason": .., "latency_s": .., "ttft_s": .., "itl_s": ..,
//!   ...}`) and the zero-length chunk.
//! * `GET /v1/metrics` — pre-reduced metrics aggregated across engine
//!   replicas (incl. TTFT/ITL statistics and percentiles), a per-replica
//!   breakdown with KV-occupancy gauges, the router's work-stealing
//!   counter, and the front-end's connection counters (`frontend.kind`,
//!   `open_connections`, `accepted`, `rejected`).
//! * `GET /health` — liveness + replica count + routing configuration +
//!   whether a serving trace is being recorded (`--record`; replayable
//!   with `pallas eval --replay`) + the same front-end counters.
//!
//! Front-ends ([`ServeOptions::frontend`], CLI `--frontend`):
//! * **`threaded`** — one thread per TCP connection, blocking I/O.
//!   Simple, but a streaming response pins its thread for the stream's
//!   lifetime, so concurrency is thread-bound.
//! * **`event-loop`** — connections multiplexed over `--loop-shards`
//!   independent loop threads (`server/event_loop.rs`), each with its
//!   own readiness back-end (`--poller`: edge-triggered `epoll` or the
//!   portable `poll(2)` fallback).  New connections arrive per
//!   `--accept`: under `reuseport` every shard binds its own
//!   `SO_REUSEPORT` listener and the kernel spreads accepts; under
//!   `handoff` shard 0 accepts and hands sockets to the least-loaded
//!   shard (`auto` picks reuseport where the kernel provides it).  The
//!   listen backlog is `--backlog` on either path.  Streaming tokens
//!   arrive as preformatted refcounted frames on per-(replica, shard)
//!   lock-free SPSC rings and are flushed with `writev(2)` without
//!   copying; engine replicas wake shards through coalescing
//!   eventfd/self-pipe wakers.  Thousands of concurrent streams cost
//!   sockets, not threads.
//!
//! Both front-ends share the parser, limits, dispatch table, and
//! response encoders in `server/conn.rs`, answer protocol violations
//! with proper `400`/`405`/`413` JSON errors, and enforce header-read +
//! idle timeouts ([`ConnLimits`], the slowloris guard).  Shutdown drains
//! gracefully: in-flight requests complete (streams keep flowing to
//! their terminal event) before the engine threads exit.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{AcceptMode, FrontendKind, PollerKind, RoutePolicy};
use crate::engine::engine::Engine;
use crate::server::conn::{self, Dispatch, DispatchCtx, ParseStatus};
pub use crate::server::conn::{ConnLimits, FrontendStats, HttpRequest};
use crate::server::event_loop::{self, ShardConfig};
use crate::server::router::{EngineRouter, ShardTx, StreamEvent, StreamFrame, STREAM_RING_CAP};
use crate::util::bufpool::BufPool;
use crate::util::json::Json;
use crate::util::spsc;
use crate::util::sys::{self, EpollPoller, PollPoller, Poller, Waker};
use crate::{log_info, log_warn};

/// Idle frame-buffer backings retained per replica pool: enough to keep
/// a full stream ring's worth of frames recycling without a single
/// steady-state allocation, without hoarding when streams go quiet.
const FRAME_POOL_CAP: usize = 2 * STREAM_RING_CAP;

/// Front-end configuration for [`serve_router_with`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Which front-end drives connections (default: threaded).
    pub frontend: FrontendKind,
    /// Readiness back-end for the event-loop front-end (default: auto —
    /// `epoll` where the kernel provides it, else `poll`).  Ignored by
    /// the threaded front-end.
    pub poller: PollerKind,
    /// Event-loop shard (thread) count; `0` is normalized to 1.  Ignored
    /// by the threaded front-end.
    pub loop_shards: usize,
    /// How event-loop shards receive connections (default: auto —
    /// per-shard `SO_REUSEPORT` listeners where the kernel provides
    /// them, else the shard-0 handoff channel).  Ignored by the threaded
    /// front-end.
    pub accept: AcceptMode,
    /// Listen backlog passed to `listen(2)` on every listener (the
    /// kernel additionally caps it at `net.core.somaxconn`); `0` is
    /// normalized to the default 1024.
    pub backlog: usize,
    /// Bench A/B knob (not on the CLI): flush event-loop connections by
    /// copying queued frames into a scratch buffer and `write(2)`-ing it
    /// instead of the vectored zero-copy path.  Semantics are
    /// byte-identical; only the flush mechanics differ.
    pub copy_flush: bool,
    /// Protocol limits and timeouts, enforced by both front-ends.
    pub limits: ConnLimits,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            frontend: FrontendKind::default(),
            poller: PollerKind::default(),
            loop_shards: 0,
            accept: AcceptMode::default(),
            backlog: 1024,
            copy_flush: false,
            limits: ConnLimits::default(),
        }
    }
}

/// Resolve one poller instance for `kind` (each shard owns its own).
/// `Epoll` is strict — an unsupported kernel is a startup error; `Auto`
/// quietly falls back to `poll(2)`.
fn make_poller(kind: PollerKind) -> Result<Box<dyn Poller>> {
    Ok(match kind {
        PollerKind::Epoll => Box::new(EpollPoller::new()?),
        PollerKind::Poll => Box::new(PollPoller::new()),
        PollerKind::Auto => match EpollPoller::new() {
            Ok(p) => Box::new(p),
            Err(_) => Box::new(PollPoller::new()),
        },
    })
}

/// Read one HTTP/1.1 request from the stream (blocking; default
/// [`ConnLimits`] apply, including the header/idle timeouts).
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    match read_request_limited(stream, &ConnLimits::default()) {
        ReadOutcome::Request(r) => Ok(r),
        ReadOutcome::Fail(status, msg) => Err(anyhow!("http {status}: {msg}")),
        ReadOutcome::Disconnected => Err(anyhow!("connection closed mid-request")),
    }
}

/// Write an HTTP response with a JSON body.
pub fn write_json(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    stream.write_all(&conn::encode_json(status, body))?;
    Ok(())
}

/// How the blocking request reader finished.
enum ReadOutcome {
    /// A complete request.
    Request(HttpRequest),
    /// Protocol violation or timeout: answer with status + message.
    Fail(u16, String),
    /// The client vanished; nothing to answer.
    Disconnected,
}

fn timeout_outcome(headers_done: bool) -> ReadOutcome {
    let msg = if headers_done {
        "idle timeout"
    } else {
        "header read timeout"
    };
    ReadOutcome::Fail(408, msg.to_string())
}

/// Blocking request read with the same limits/timeouts the event loop
/// enforces: the socket read deadline tracks the header/idle budget, and
/// the shared incremental parser supplies identical error responses.
fn read_request_limited(stream: &mut TcpStream, limits: &ConnLimits) -> ReadOutcome {
    let start = Instant::now();
    let mut last_byte = start;
    let mut buf: Vec<u8> = Vec::new();
    let mut headers_done = false;
    let mut chunk = [0u8; 16 * 1024];
    loop {
        let now = Instant::now();
        let idle_deadline = last_byte + limits.idle_timeout;
        let deadline = if headers_done {
            idle_deadline
        } else {
            idle_deadline.min(start + limits.header_timeout)
        };
        if now >= deadline {
            return timeout_outcome(headers_done);
        }
        if stream.set_read_timeout(Some(deadline - now)).is_err() {
            return ReadOutcome::Disconnected;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Disconnected,
            Ok(n) => {
                last_byte = Instant::now();
                buf.extend_from_slice(&chunk[..n]);
                if !headers_done {
                    headers_done = conn::header_end(&buf).is_some();
                }
                match conn::parse_request(&buf, limits) {
                    ParseStatus::Partial => {}
                    ParseStatus::Complete(r) => return ReadOutcome::Request(r),
                    ParseStatus::Invalid(status, msg) => {
                        return ReadOutcome::Fail(status, msg.to_string());
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return timeout_outcome(headers_done);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Disconnected,
        }
    }
}

/// Serve one `"stream": true` completion on the threaded front-end:
/// chunked NDJSON via the shared line builders, so the bytes match the
/// event-loop front-end exactly.
fn serve_streaming_blocking(stream: &mut TcpStream, rx: Receiver<StreamEvent>) {
    if stream.write_all(conn::STREAM_HEADER).is_err() {
        return; // client already gone; the replica drops the stream lazily
    }
    let mut got_done = false;
    for ev in rx {
        let (line, is_done) = match ev {
            StreamEvent::Delta { tokens, t } => (conn::delta_line(&tokens, t), false),
            StreamEvent::Done(fin) => (conn::done_line(&fin), true),
        };
        if stream.write_all(&conn::encode_chunk_line(&line)).is_err() {
            return; // client hung up mid-stream
        }
        if is_done {
            got_done = true;
            break;
        }
    }
    if !got_done {
        // the replica exited without a terminal event (shutdown race):
        // tell the client explicitly instead of truncating silently
        let _ = stream.write_all(&conn::encode_chunk_line(&conn::aborted_line()));
    }
    let _ = stream.write_all(conn::STREAM_TERMINATOR);
}

fn handle_conn(
    mut stream: TcpStream,
    router: &EngineRouter,
    stats: &FrontendStats,
    limits: &ConnLimits,
) {
    let req = match read_request_limited(&mut stream, limits) {
        ReadOutcome::Request(r) => r,
        ReadOutcome::Fail(status, msg) => {
            let _ = stream.write_all(&conn::encode_error(status, &msg));
            conn::drain_before_close(&mut stream);
            return;
        }
        ReadOutcome::Disconnected => return,
    };
    // request fully read: lift the read deadline — engine waits may
    // legitimately exceed the idle budget.  The *write* deadline stays:
    // a client that stops reading its response would otherwise pin this
    // thread (and its connection slot) forever.
    let _ = stream.set_read_timeout(None);
    let _ = stream.set_write_timeout(Some(limits.idle_timeout));
    match conn::dispatch(&req, router, stats, DispatchCtx::Threaded) {
        Dispatch::Immediate(bytes) => {
            let _ = stream.write_all(&bytes);
        }
        Dispatch::Blocking(rx) => {
            let bytes = match rx.recv() {
                Ok(fin) => conn::encode_json(200, &conn::blocking_body(&fin)),
                Err(_) => conn::encode_error(500, "aborted"),
            };
            let _ = stream.write_all(&bytes);
        }
        Dispatch::Streaming(rx) => serve_streaming_blocking(&mut stream, rx),
        Dispatch::StreamingRing => unreachable!("ring streaming is event-loop-only"),
    }
}

/// Handle used to submit work / stop the server.
pub struct ServerHandle {
    /// The bound listen address (useful with `"127.0.0.1:0"`).
    pub addr: std::net::SocketAddr,
    router: Arc<EngineRouter>,
    stop: Arc<AtomicBool>,
    serving_threads: Vec<JoinHandle<()>>,
    stats: Arc<FrontendStats>,
    wakers: Vec<Arc<Waker>>,
}

impl ServerHandle {
    /// The router behind this server (e.g. for metric snapshots in-process).
    pub fn router(&self) -> &EngineRouter {
        &self.router
    }

    /// The front-end's connection counters (also on `/health` and
    /// `/v1/metrics`).
    pub fn frontend_stats(&self) -> &FrontendStats {
        &self.stats
    }

    /// Stop accepting connections, then drain the engine replicas: every
    /// in-flight request completes and is delivered before this returns.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if self.wakers.is_empty() {
            // threaded: poke the acceptor so it notices the stop flag;
            // connection threads finish via the drain
            let _ = TcpStream::connect(self.addr);
            for t in self.serving_threads.drain(..) {
                let _ = t.join();
            }
            self.router.shutdown();
        } else {
            // event loop: the stop flag ends accepting; the drain below
            // keeps every shard awake for its terminal ring frames, and
            // each shard exits once its last connection flushes
            for w in &self.wakers {
                w.wake();
            }
            self.router.shutdown();
            for w in &self.wakers {
                w.wake();
            }
            for t in self.serving_threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

/// Serve a single engine on `addr` (wraps it in a 1-replica router).
pub fn serve(engine: Engine, addr: &str) -> Result<ServerHandle> {
    serve_router(
        EngineRouter::new(vec![engine], RoutePolicy::RoundRobin),
        addr,
    )
}

/// Serve a replica set on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port) with the default options (threaded front-end).
pub fn serve_router(router: EngineRouter, addr: &str) -> Result<ServerHandle> {
    serve_router_with(router, addr, ServeOptions::default())
}

/// Serve a replica set on `addr` with an explicit front-end choice and
/// protocol limits.
pub fn serve_router_with(
    router: EngineRouter,
    addr: &str,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    let sock_addr = {
        use std::net::ToSocketAddrs;
        addr.to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("cannot resolve listen address {addr}"))?
    };
    let backlog = opts.backlog.max(1).min(i32::MAX as usize);
    let router = Arc::new(router);
    let stop = Arc::new(AtomicBool::new(false));
    let limits = opts.limits;
    let (serving_threads, wakers, stats, local) = match opts.frontend {
        FrontendKind::Threaded => {
            let listener = sys::bind_listener(sock_addr, backlog as i32, false)?;
            let local = listener.local_addr()?;
            let stats = Arc::new(FrontendStats::new(opts.frontend, backlog));
            let stop_a = stop.clone();
            let router_a = router.clone();
            let stats_a = stats.clone();
            let t = std::thread::Builder::new()
                .name("dsde-http-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop_a.load(Ordering::SeqCst) {
                            break;
                        }
                        match stream {
                            Ok(s) => {
                                if stats_a.open() >= limits.max_open_conns {
                                    stats_a.on_reject();
                                    // reject off-thread: the blocking
                                    // write + drain must not stall the
                                    // acceptor under a rejection storm
                                    std::thread::spawn(move || {
                                        let mut s = s;
                                        let _ = s.write_all(&conn::encode_error(
                                            503,
                                            "server at capacity",
                                        ));
                                        conn::drain_before_close(&mut s);
                                    });
                                    continue;
                                }
                                stats_a.on_accept();
                                let router = router_a.clone();
                                let stats = stats_a.clone();
                                std::thread::spawn(move || {
                                    handle_conn(s, &router, &stats, &limits);
                                    stats.on_close();
                                });
                            }
                            Err(e) => log_warn!("accept error: {e}"),
                        }
                    }
                })
                .expect("spawn acceptor thread");
            (vec![t], Vec::new(), stats, local)
        }
        FrontendKind::EventLoop => {
            let shards = opts.loop_shards.max(1);
            // resolve every shard's poller up front so a strict
            // `--poller epoll` on an unsupported kernel fails at startup
            let mut pollers: Vec<Box<dyn Poller>> = Vec::with_capacity(shards);
            for _ in 0..shards {
                pollers.push(make_poller(opts.poller)?);
            }
            let poller_name = pollers[0].name();
            // resolve the accept mode, binding listeners accordingly:
            // reuseport gives every shard its own listener on one port
            // (the kernel spreads accepts); handoff gives shard 0 the
            // single listener.  `auto` probes reuseport on the first
            // bind and quietly falls back.
            let mut listeners: Vec<Option<TcpListener>> = Vec::with_capacity(shards);
            let accept_name: &'static str;
            match opts.accept {
                AcceptMode::Handoff => {
                    listeners.push(Some(sys::bind_listener(
                        sock_addr,
                        backlog as i32,
                        false,
                    )?));
                    listeners.resize_with(shards, || None);
                    accept_name = "handoff";
                }
                mode => match sys::bind_listener(sock_addr, backlog as i32, true) {
                    Ok(first) => {
                        // bind the remaining shards to the *resolved*
                        // address — `:0` picked an ephemeral port the
                        // siblings must share
                        let bound = first.local_addr()?;
                        listeners.push(Some(first));
                        for _ in 1..shards {
                            listeners.push(Some(sys::bind_listener(
                                bound,
                                backlog as i32,
                                true,
                            )?));
                        }
                        accept_name = "reuseport";
                    }
                    Err(e) if mode == AcceptMode::Auto => {
                        listeners.push(Some(sys::bind_listener(
                            sock_addr,
                            backlog as i32,
                            false,
                        )?));
                        listeners.resize_with(shards, || None);
                        accept_name = "handoff";
                        log_info!("SO_REUSEPORT unavailable ({e}); accept mode: handoff");
                    }
                    Err(e) => {
                        return Err(anyhow!("--accept reuseport: cannot bind: {e}"));
                    }
                },
            }
            let local = listeners[0]
                .as_ref()
                .expect("shard 0 always has a listener")
                .local_addr()?;
            let stats = Arc::new(FrontendStats::with_loop(
                opts.frontend,
                poller_name,
                accept_name,
                backlog,
                shards,
            ));
            let mut wakers: Vec<Arc<Waker>> = Vec::with_capacity(shards);
            for _ in 0..shards {
                wakers.push(Arc::new(Waker::new()?));
            }
            // one SPSC stream ring per (replica, shard) pair: replicas
            // keep the producers, shards the consumers.  Attached before
            // the listener starts, so the FIFO engine channels guarantee
            // the rings are installed ahead of any ring submission.
            let mut per_replica: Vec<(Vec<ShardTx>, BufPool)> = Vec::new();
            let mut per_shard_rings: Vec<Vec<spsc::Consumer<StreamFrame>>> =
                (0..shards).map(|_| Vec::new()).collect();
            let (pool_hits, pool_misses) = stats.bufpool_counters();
            for _ in 0..router.replica_count() {
                let mut row = Vec::with_capacity(shards);
                for (s, rings) in per_shard_rings.iter_mut().enumerate() {
                    let (tx, rx) = spsc::ring(STREAM_RING_CAP);
                    row.push(ShardTx::new(tx, wakers[s].clone()));
                    rings.push(rx);
                }
                // one frame pool per replica (producer-local, so pool
                // recycling never contends across replica threads);
                // hit/miss counters aggregate into the shared stats
                per_replica.push((
                    row,
                    BufPool::with_counters(
                        FRAME_POOL_CAP,
                        pool_hits.clone(),
                        pool_misses.clone(),
                    ),
                ));
            }
            router.attach_stream_shards(per_replica);
            // handoff channels: shard 0 accepts and hands sockets to the
            // shard with the fewest open connections (handoff mode only —
            // under reuseport the kernel already sharded the accept)
            type Handoff = (TcpStream, u64);
            let mut handoff_txs: Vec<(Sender<Handoff>, Arc<Waker>)> = Vec::new();
            let mut handoff_rxs: Vec<Receiver<Handoff>> = Vec::new();
            if accept_name == "handoff" {
                for s in 1..shards {
                    let (tx, rx) = channel();
                    handoff_txs.push((tx, wakers[s].clone()));
                    handoff_rxs.push(rx);
                }
            }
            let next_token = Arc::new(AtomicU64::new(1));
            let mut threads = Vec::with_capacity(shards);
            let mut handoff_rxs = handoff_rxs.into_iter();
            for (s, (poller, rings)) in
                pollers.into_iter().zip(per_shard_rings).enumerate()
            {
                let cfg = ShardConfig {
                    id: s,
                    poller,
                    waker: wakers[s].clone(),
                    listener: listeners[s].take(),
                    handoff_rx: if s == 0 { None } else { handoff_rxs.next() },
                    handoff_txs: if s == 0 {
                        std::mem::take(&mut handoff_txs)
                    } else {
                        Vec::new()
                    },
                    rings,
                    router: router.clone(),
                    stats: stats.clone(),
                    stop: stop.clone(),
                    limits,
                    next_token: next_token.clone(),
                    copy_flush: opts.copy_flush,
                };
                let t = std::thread::Builder::new()
                    .name(format!("dsde-http-loop-{s}"))
                    .spawn(move || event_loop::run_shard(cfg))
                    .expect("spawn event loop shard");
                threads.push(t);
            }
            (threads, wakers, stats, local)
        }
    };
    log_info!(
        "serving on http://{local} ({} replica(s), {}, {} front-end, \
         poller {}, accept {}, backlog {}, {} loop shard(s))",
        router.replica_count(),
        router.policy().name(),
        opts.frontend.name(),
        stats.poller(),
        stats.accept_mode(),
        stats.backlog(),
        stats.loop_shards()
    );
    Ok(ServerHandle {
        addr: local,
        router,
        stop,
        serving_threads,
        stats,
        wakers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engine(seed: u64) -> Engine {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            policy: SlPolicyKind::Dsde(Default::default()),
            seed,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), seed);
        Engine::new(cfg, Box::new(model))
    }

    fn sim_server() -> ServerHandle {
        serve(sim_engine(1), "127.0.0.1:0").unwrap()
    }

    fn sim_server_replicated(n: usize) -> ServerHandle {
        let engines = (0..n).map(|i| sim_engine(1 + i as u64)).collect();
        serve_router(
            EngineRouter::new(engines, RoutePolicy::RoundRobin),
            "127.0.0.1:0",
        )
        .unwrap()
    }

    fn raw_request(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_endpoint() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.contains("\"ok\":true"));
        assert!(resp.contains("\"replicas\":1"));
        assert!(resp.contains("\"recording\":false"), "{resp}");
        assert!(resp.contains("\"kind\":\"threaded\""), "{resp}");
        h.shutdown();
    }

    #[test]
    fn health_reports_routing_config() {
        let engines = (0..2).map(|i| sim_engine(1 + i as u64)).collect();
        let h = serve_router(
            EngineRouter::with_options(engines, RoutePolicy::KvAware, true),
            "127.0.0.1:0",
        )
        .unwrap();
        let resp = raw_request(
            h.addr,
            "GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"route\":\"kv-aware\""), "{resp}");
        assert!(resp.contains("\"steal\":true"), "{resp}");
        let resp = raw_request(
            h.addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"kv_free_blocks\""), "{resp}");
        assert!(resp.contains("\"queued_prompt_tokens\""), "{resp}");
        assert!(resp.contains("\"steals\":"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let h = sim_server();
        let body = r#"{"prompt": "def compute(x):", "max_tokens": 12}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"tokens\":12"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn metrics_endpoint() {
        let h = sim_server();
        let body = r#"{"prompt": "hi", "max_tokens": 4}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        raw_request(h.addr, &req);
        let resp = raw_request(
            h.addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("block_efficiency"), "{resp}");
        assert!(resp.contains("route_policy"), "{resp}");
        assert!(resp.contains("\"accepted\":"), "{resp}");
        assert!(resp.contains("\"open_connections\":"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn streaming_raw_response_has_chunked_framing() {
        let h = sim_server();
        let body = r#"{"prompt": "hi", "max_tokens": 8, "stream": true}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("Transfer-Encoding: chunked"), "{resp}");
        assert!(resp.contains("\"done\":true"), "{resp}");
        assert!(resp.contains("\"finish_reason\":\"max_tokens\""), "{resp}");
        assert!(resp.ends_with("0\r\n\r\n"), "terminal chunk missing: {resp:?}");
        h.shutdown();
    }

    #[test]
    fn streaming_run_populates_ttft_metrics() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let r = crate::server::client::complete_streaming(&addr, "hello world", 16, 0.0)
            .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.tokens(), 16);
        assert!(
            r.finale.get("ttft_s").and_then(|x| x.as_f64()).unwrap() > 0.0,
            "{:?}",
            r.finale
        );
        // the aggregated serving metrics carry non-zero TTFT statistics
        let m = crate::server::client::metrics(&addr).unwrap();
        assert!(
            m.get("mean_ttft").and_then(|x| x.as_f64()).unwrap() > 0.0,
            "{m}"
        );
        assert!(m.get("p99_ttft").is_some(), "{m}");
        assert!(m.get("mean_itl").is_some(), "{m}");
        h.shutdown();
    }

    #[test]
    fn bad_json_is_400() {
        let h = sim_server();
        let body = "{nope";
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn unknown_path_404() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 404"));
        h.shutdown();
    }

    #[test]
    fn wrong_method_is_405() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "POST /health HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        assert!(resp.contains("\"error\""), "{resp}");
        let resp = raw_request(
            h.addr,
            "GET /v1/completions HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn oversized_body_is_413() {
        let h = sim_server();
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            ConnLimits::default().max_body_bytes + 1
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        assert!(resp.contains("\"error\""), "{resp}");
        h.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let h = sim_server();
        let addr = h.addr;
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let body =
                        format!(r#"{{"prompt": "req {i}", "max_tokens": 16}}"#);
                    let req = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                         Content-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    raw_request(addr, &req)
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        }
        h.shutdown();
    }

    #[test]
    fn replicated_server_completes_and_aggregates() {
        let h = sim_server_replicated(2);
        let addr = h.addr;
        let threads: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let body =
                        format!(r#"{{"prompt": "req {i}", "max_tokens": 8}}"#);
                    let req = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                         Content-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    raw_request(addr, &req)
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        }
        let resp = raw_request(
            addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"replica_count\":2"), "{resp}");
        assert!(resp.contains("\"requests\":6"), "{resp}");
        h.shutdown();
    }
}
