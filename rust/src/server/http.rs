//! Minimal HTTP/1.1 server with a JSON completions API.
//!
//! Endpoints:
//! * `POST /v1/completions` — body `{"prompt": "...", "max_tokens": N,
//!   "temperature": T}` → `{"id": .., "text": .., "latency_s": ..,
//!   "ttft_s": .., "rounds": ..}` (blocks until the request completes).
//!   With `"stream": true` the response switches to HTTP/1.1 chunked
//!   transfer-encoding carrying newline-delimited JSON: one line per
//!   accepted-token delta (`{"text": .., "tokens": .., "t": ..}`) as the
//!   engine applies it, then a terminal line (`{"done": true,
//!   "finish_reason": .., "latency_s": .., "ttft_s": .., "itl_s": ..,
//!   ...}`) and the zero-length chunk.
//! * `GET /v1/metrics` — pre-reduced metrics aggregated across engine
//!   replicas (incl. TTFT/ITL statistics and percentiles), plus a
//!   per-replica breakdown with KV-occupancy gauges (`kv_used_blocks`,
//!   `kv_free_blocks`, `queued_requests`, `queued_prompt_tokens`) and the
//!   router's work-stealing counter.
//! * `GET /health` — liveness + replica count + routing configuration.
//!
//! Connection threads hand requests to an [`EngineRouter`], which owns one
//! engine thread per replica; [`serve`] wraps a single engine in a
//! 1-replica router, [`serve_router`] serves an arbitrary replica set.
//! Shutdown drains gracefully: in-flight requests complete (streams keep
//! flowing to their terminal event) before the engine threads exit.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::RoutePolicy;
use crate::engine::engine::Engine;
use crate::engine::request::{Request, SamplingParams};
use crate::model::vocab;
use crate::server::router::{EngineRouter, StreamEvent};
use crate::util::json::Json;
use crate::{log_info, log_warn};

/// A parsed HTTP request (the subset we serve).
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method (`GET`, `POST`, ...).
    pub method: String,
    /// Request path, e.g. `/v1/completions`.
    pub path: String,
    /// Raw request body (sized by `Content-Length`).
    pub body: String,
}

/// Read one HTTP/1.1 request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Write an HTTP response with a JSON body.
pub fn write_json(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let body = body.to_string();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    Ok(())
}

/// Write one chunk of an HTTP/1.1 chunked-transfer-encoding body.
fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{data}\r\n", data.len())
}

/// Serve one `"stream": true` completion: chunked NDJSON with one line per
/// accepted-token delta, then a terminal line carrying the finish reason
/// and per-request metrics, then the zero-length chunk.
fn serve_streaming(stream: &mut TcpStream, router: &EngineRouter, request: Request) {
    let rx = router.submit_streaming(request);
    if write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )
    .is_err()
    {
        return; // client already gone; the replica drops the stream lazily
    }
    let mut got_done = false;
    for ev in rx {
        let (line, is_done) = match ev {
            StreamEvent::Delta { tokens, t } => (
                Json::obj()
                    .set("text", vocab::decode(&tokens))
                    .set("tokens", tokens.len())
                    .set("t", t)
                    .to_string(),
                false,
            ),
            StreamEvent::Done(fin) => (
                Json::obj()
                    .set("done", true)
                    .set("id", fin.id)
                    .set("finish_reason", fin.reason.name())
                    .set("tokens", fin.output.len())
                    .set("latency_s", fin.latency())
                    .set("ttft_s", fin.ttft())
                    .set("itl_s", fin.itl())
                    .set("rounds", fin.rounds)
                    .set("accepted", fin.accepted)
                    .set("drafted", fin.drafted)
                    .to_string(),
                true,
            ),
        };
        if write_chunk(stream, &format!("{line}\n")).is_err() {
            return; // client hung up mid-stream
        }
        if is_done {
            got_done = true;
            break;
        }
    }
    if !got_done {
        // the replica exited without a terminal event (shutdown race):
        // tell the client explicitly instead of truncating silently
        let line = Json::obj()
            .set("done", true)
            .set("finish_reason", "aborted")
            .to_string();
        let _ = write_chunk(stream, &format!("{line}\n"));
    }
    let _ = stream.write_all(b"0\r\n\r\n");
}

/// Handle used to submit work / stop the server.
pub struct ServerHandle {
    /// The bound listen address (useful with `"127.0.0.1:0"`).
    pub addr: std::net::SocketAddr,
    router: Arc<EngineRouter>,
    stop: Arc<AtomicBool>,
    acceptor_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The router behind this server (e.g. for metric snapshots in-process).
    pub fn router(&self) -> &EngineRouter {
        &self.router
    }

    /// Stop accepting connections, then drain the engine replicas: every
    /// in-flight request completes and is delivered before this returns.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor so it notices the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.acceptor_thread.take() {
            let _ = t.join();
        }
        self.router.shutdown();
    }
}

fn handle_conn(mut stream: TcpStream, router: &EngineRouter) {
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(_) => return,
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let body = Json::obj()
                .set("ok", true)
                .set("replicas", router.replica_count())
                .set("route", router.policy().name())
                .set("steal", router.stealing_enabled());
            let _ = write_json(&mut stream, 200, &body);
        }
        ("GET", "/v1/metrics") => {
            let _ = write_json(&mut stream, 200, &router.metrics_json());
        }
        ("POST", "/v1/completions") => {
            let parsed = match Json::parse(&req.body) {
                Ok(j) => j,
                Err(e) => {
                    let _ = write_json(
                        &mut stream,
                        400,
                        &Json::obj().set("error", format!("bad json: {e}")),
                    );
                    return;
                }
            };
            let Some(prompt) = parsed.get("prompt").and_then(|p| p.as_str()) else {
                let _ = write_json(
                    &mut stream,
                    400,
                    &Json::obj().set("error", "missing 'prompt'"),
                );
                return;
            };
            let max_tokens = parsed
                .get("max_tokens")
                .and_then(|x| x.as_usize())
                .unwrap_or(64);
            let temperature = parsed
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0);
            let streaming = parsed
                .get("stream")
                .and_then(|x| x.as_bool())
                .unwrap_or(false);
            let request = Request::new(
                0, // the router assigns the globally unique id
                vocab::encode(prompt),
                SamplingParams {
                    temperature,
                    max_tokens,
                    stop_token: None,
                },
            );
            if streaming {
                serve_streaming(&mut stream, router, request);
                return;
            }
            match router.complete(request) {
                Ok(fin) => {
                    let body = Json::obj()
                        .set("id", fin.id)
                        .set("text", fin.output_text())
                        .set("tokens", fin.output.len())
                        .set("finish_reason", fin.reason.name())
                        .set("latency_s", fin.latency())
                        .set("ttft_s", fin.ttft())
                        .set("itl_s", fin.itl())
                        .set("rounds", fin.rounds)
                        .set("accepted", fin.accepted)
                        .set("drafted", fin.drafted);
                    let _ = write_json(&mut stream, 200, &body);
                }
                Err(_) => {
                    let _ =
                        write_json(&mut stream, 500, &Json::obj().set("error", "aborted"));
                }
            }
        }
        _ => {
            let _ = write_json(&mut stream, 404, &Json::obj().set("error", "not found"));
        }
    }
}

/// Serve a single engine on `addr` (wraps it in a 1-replica router).
pub fn serve(engine: Engine, addr: &str) -> Result<ServerHandle> {
    serve_router(
        EngineRouter::new(vec![engine], RoutePolicy::RoundRobin),
        addr,
    )
}

/// Serve a replica set on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port).  Connection threads dispatch through the router's policy.
pub fn serve_router(router: EngineRouter, addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let router = Arc::new(router);
    let stop = Arc::new(AtomicBool::new(false));
    let stop_a = stop.clone();
    let router_a = router.clone();
    let acceptor_thread = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop_a.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let router = router_a.clone();
                    std::thread::spawn(move || handle_conn(s, &router));
                }
                Err(e) => log_warn!("accept error: {e}"),
            }
        }
    });
    log_info!(
        "serving on http://{local} ({} replica(s), {})",
        router.replica_count(),
        router.policy().name()
    );
    Ok(ServerHandle {
        addr: local,
        router,
        stop,
        acceptor_thread: Some(acceptor_thread),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engine(seed: u64) -> Engine {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            policy: SlPolicyKind::Dsde(Default::default()),
            seed,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), seed);
        Engine::new(cfg, Box::new(model))
    }

    fn sim_server() -> ServerHandle {
        serve(sim_engine(1), "127.0.0.1:0").unwrap()
    }

    fn sim_server_replicated(n: usize) -> ServerHandle {
        let engines = (0..n).map(|i| sim_engine(1 + i as u64)).collect();
        serve_router(
            EngineRouter::new(engines, RoutePolicy::RoundRobin),
            "127.0.0.1:0",
        )
        .unwrap()
    }

    fn raw_request(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_endpoint() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.contains("\"ok\":true"));
        assert!(resp.contains("\"replicas\":1"));
        h.shutdown();
    }

    #[test]
    fn health_reports_routing_config() {
        let engines = (0..2).map(|i| sim_engine(1 + i as u64)).collect();
        let h = serve_router(
            EngineRouter::with_options(engines, RoutePolicy::KvAware, true),
            "127.0.0.1:0",
        )
        .unwrap();
        let resp = raw_request(
            h.addr,
            "GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"route\":\"kv-aware\""), "{resp}");
        assert!(resp.contains("\"steal\":true"), "{resp}");
        let resp = raw_request(
            h.addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"kv_free_blocks\""), "{resp}");
        assert!(resp.contains("\"queued_prompt_tokens\""), "{resp}");
        assert!(resp.contains("\"steals\":"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let h = sim_server();
        let body = r#"{"prompt": "def compute(x):", "max_tokens": 12}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("\"tokens\":12"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn metrics_endpoint() {
        let h = sim_server();
        let body = r#"{"prompt": "hi", "max_tokens": 4}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        raw_request(h.addr, &req);
        let resp = raw_request(
            h.addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("block_efficiency"), "{resp}");
        assert!(resp.contains("route_policy"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn streaming_raw_response_has_chunked_framing() {
        let h = sim_server();
        let body = r#"{"prompt": "hi", "max_tokens": 8, "stream": true}"#;
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("Transfer-Encoding: chunked"), "{resp}");
        assert!(resp.contains("\"done\":true"), "{resp}");
        assert!(resp.contains("\"finish_reason\":\"max_tokens\""), "{resp}");
        assert!(resp.ends_with("0\r\n\r\n"), "terminal chunk missing: {resp:?}");
        h.shutdown();
    }

    #[test]
    fn streaming_run_populates_ttft_metrics() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let r = crate::server::client::complete_streaming(&addr, "hello world", 16, 0.0)
            .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.tokens(), 16);
        assert!(
            r.finale.get("ttft_s").and_then(|x| x.as_f64()).unwrap() > 0.0,
            "{:?}",
            r.finale
        );
        // the aggregated serving metrics carry non-zero TTFT statistics
        let m = crate::server::client::metrics(&addr).unwrap();
        assert!(
            m.get("mean_ttft").and_then(|x| x.as_f64()).unwrap() > 0.0,
            "{m}"
        );
        assert!(m.get("p99_ttft").is_some(), "{m}");
        assert!(m.get("mean_itl").is_some(), "{m}");
        h.shutdown();
    }

    #[test]
    fn bad_json_is_400() {
        let h = sim_server();
        let body = "{nope";
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = raw_request(h.addr, &req);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        h.shutdown();
    }

    #[test]
    fn unknown_path_404() {
        let h = sim_server();
        let resp = raw_request(
            h.addr,
            "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 404"));
        h.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let h = sim_server();
        let addr = h.addr;
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let body =
                        format!(r#"{{"prompt": "req {i}", "max_tokens": 16}}"#);
                    let req = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                         Content-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    raw_request(addr, &req)
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        }
        h.shutdown();
    }

    #[test]
    fn replicated_server_completes_and_aggregates() {
        let h = sim_server_replicated(2);
        let addr = h.addr;
        let threads: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let body =
                        format!(r#"{{"prompt": "req {i}", "max_tokens": 8}}"#);
                    let req = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                         Content-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    raw_request(addr, &req)
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        }
        let resp = raw_request(
            addr,
            "GET /v1/metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        );
        assert!(resp.contains("\"replica_count\":2"), "{resp}");
        assert!(resp.contains("\"requests\":6"), "{resp}");
        h.shutdown();
    }
}
