//! Multi-replica engine router: horizontal scale for the serving stack.
//!
//! An [`EngineRouter`] owns N engine replicas — each with its own model
//! instance, KV cache, scheduler, and dedicated thread running the staged
//! `plan → execute → apply` loop — and dispatches requests to them by a
//! pluggable [`RoutePolicy`] (round-robin or least-loaded by in-flight
//! count).  It aggregates [`MetricsSnapshot`]s across replicas for
//! `/v1/metrics` and performs a graceful drain on shutdown: every replica
//! finishes its in-flight batch before its thread exits.
//!
//! Requests can complete two ways:
//! * [`EngineRouter::submit`] / [`EngineRouter::complete`] — one
//!   [`FinishedRequest`] when the whole output exists;
//! * [`EngineRouter::submit_streaming`] — a [`StreamEvent`] channel that
//!   carries every accepted-token delta as the engine's step loop applies
//!   it ([`StreamEvent::Delta`]), then the finished-request summary
//!   ([`StreamEvent::Done`]); the channel closes after the terminal event.
//!   Drain still delivers every delta and the terminal event; abort
//!   terminates open streams with a `FinishReason::Aborted` summary.
//!
//! Replicas are share-nothing: no KV or signal state crosses the boundary,
//! so aggregate throughput scales with replica count until the host runs
//! out of cores (see `benches/serving_load.rs`).  Cross-replica KV-aware
//! placement is the designed follow-on (ROADMAP).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::RoutePolicy;
use crate::engine::engine::{Engine, StepOutcome};
use crate::engine::metrics::{MetricsSnapshot, DEFAULT_QUANTILES};
use crate::engine::request::{FinishedRequest, Request};
use crate::engine::step::StepReport;
use crate::util::json::Json;
use crate::log_warn;

/// One event on a streaming request's channel.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Tokens accepted for this request in one engine step.
    Delta {
        /// The accepted tokens, in generation order.
        tokens: Vec<u32>,
        /// Engine-clock time the tokens were applied at.
        t: f64,
    },
    /// Terminal event: the completed request summary.  The channel closes
    /// after this is delivered.
    Done(FinishedRequest),
}

/// Messages into a replica's engine thread.
pub(crate) enum EngineMsg {
    /// Submit a request; the finished result is sent on the reply channel.
    Submit(Request, Sender<FinishedRequest>),
    /// Submit a request whose per-step token deltas (and terminal summary)
    /// are forwarded on the reply channel as they happen.
    SubmitStreaming(Request, Sender<StreamEvent>),
    /// Snapshot this replica's metrics, pre-reduced to scalars plus the
    /// requested percentiles (never the full retained request window).
    Metrics(Vec<f64>, Sender<MetricsSnapshot>),
    /// Graceful drain: finish everything in flight, then exit the thread.
    Drain,
    /// Abort in-flight work (clients observe `FinishReason::Aborted`) and
    /// exit the thread.
    Abort,
}

/// One engine replica: channel + thread + in-flight counter.
struct Replica {
    tx: Sender<EngineMsg>,
    load: Arc<AtomicUsize>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

/// Deliver finished requests to their waiting reply channels — blocking
/// submitters get the [`FinishedRequest`], streaming subscribers get the
/// terminal [`StreamEvent::Done`] (which also closes their channel).
fn deliver(
    engine: &mut Engine,
    pending: &mut HashMap<u64, Sender<FinishedRequest>>,
    streams: &mut HashMap<u64, Sender<StreamEvent>>,
    load: &AtomicUsize,
) {
    for fin in engine.take_finished() {
        load.fetch_sub(1, Ordering::SeqCst);
        if let Some(reply) = pending.remove(&fin.id) {
            let _ = reply.send(fin);
        } else if let Some(reply) = streams.remove(&fin.id) {
            let _ = reply.send(StreamEvent::Done(fin));
        }
    }
    // orphaned waiters (should not happen): drop their channels so callers
    // error out instead of hanging — and release their load slots so
    // least-loaded routing does not shun this replica forever
    if engine.pending() == 0 && (!pending.is_empty() || !streams.is_empty()) {
        load.fetch_sub(pending.len() + streams.len(), Ordering::SeqCst);
        pending.clear();
        streams.clear();
    }
}

/// Forward one step's accepted-token deltas to their streaming
/// subscribers.  Takes the report by value so the token vectors move into
/// the channel instead of being cloned on the per-step hot path.  A
/// hung-up subscriber is dropped from the map — its request still runs to
/// completion and is accounted normally; only the forwarding stops.
fn forward_deltas(
    report: StepReport,
    streams: &mut HashMap<u64, Sender<StreamEvent>>,
) {
    for d in report.deltas {
        let dead = match streams.get(&d.id) {
            Some(tx) => tx
                .send(StreamEvent::Delta {
                    tokens: d.tokens,
                    t: d.t,
                })
                .is_err(),
            None => false,
        };
        if dead {
            streams.remove(&d.id);
        }
    }
}

/// A replica's engine thread: interleave request intake with engine steps
/// so new arrivals join the continuous batch.
fn replica_loop(
    mut engine: Engine,
    rx: Receiver<EngineMsg>,
    load: Arc<AtomicUsize>,
) {
    let mut pending: HashMap<u64, Sender<FinishedRequest>> = HashMap::new();
    let mut streams: HashMap<u64, Sender<StreamEvent>> = HashMap::new();
    let mut draining = false;
    let mut consecutive_errors = 0u32;
    loop {
        // drain the message queue (blocking when idle, else non-blocking)
        loop {
            let idle = engine.pending() == 0
                && pending.is_empty()
                && streams.is_empty()
                && !draining;
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return, // router dropped: nothing in flight
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true; // router gone: finish what we hold
                        break;
                    }
                }
            };
            match msg {
                EngineMsg::Submit(req, reply) => {
                    pending.insert(req.id, reply);
                    engine.submit(req);
                }
                EngineMsg::SubmitStreaming(req, reply) => {
                    streams.insert(req.id, reply);
                    engine.submit(req);
                }
                EngineMsg::Metrics(quantiles, reply) => {
                    let _ = reply.send(engine.metrics.snapshot(&quantiles));
                }
                EngineMsg::Drain => draining = true,
                EngineMsg::Abort => {
                    engine.abort_all();
                    deliver(&mut engine, &mut pending, &mut streams, &load);
                    return;
                }
            }
        }
        if engine.pending() > 0 {
            let progressed = match engine.step_detailed() {
                Ok(outcome) => {
                    consecutive_errors = 0;
                    match outcome {
                        StepOutcome::Idle => false,
                        StepOutcome::Retry => true,
                        StepOutcome::Ran(report) => {
                            forward_deltas(report, &mut streams);
                            true
                        }
                    }
                }
                Err(e) => {
                    consecutive_errors += 1;
                    log_warn!(
                        "engine step error ({consecutive_errors} consecutive): {e:#}"
                    );
                    // a transient failure is worth retrying; a persistently
                    // failing model must not wedge the replica forever
                    consecutive_errors < 3
                }
            };
            deliver(&mut engine, &mut pending, &mut streams, &load);
            if !progressed && engine.pending() > 0 {
                // Stuck, not just slow.  Two causes, two remedies — either
                // way the replica stays up instead of busy-spinning and
                // starving everything routed here:
                if consecutive_errors >= 3 {
                    // persistently failing model: the whole batch is
                    // unservable; clients observe FinishReason::Aborted
                    log_warn!(
                        "model failing persistently; aborting {} request(s)",
                        engine.pending()
                    );
                    engine.abort_all();
                    consecutive_errors = 0;
                } else {
                    // head-of-line prompt that can never fit in KV (FCFS
                    // forbids skipping it): abort just the head so the
                    // servable requests queued behind it proceed
                    if let Some(id) = engine.abort_head() {
                        log_warn!(
                            "aborting unschedulable request {id} \
                             (prompt cannot fit in KV)"
                        );
                    }
                }
                deliver(&mut engine, &mut pending, &mut streams, &load);
            }
        } else if draining {
            return;
        }
    }
}

/// Routes requests across engine replicas; aggregates their metrics.
pub struct EngineRouter {
    replicas: Vec<Replica>,
    policy: RoutePolicy,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
}

impl EngineRouter {
    /// Spawn one serving thread per engine.  Panics on an empty replica
    /// set (a router with nothing behind it cannot serve).
    pub fn new(engines: Vec<Engine>, policy: RoutePolicy) -> EngineRouter {
        assert!(!engines.is_empty(), "EngineRouter needs >= 1 engine");
        let replicas = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let (tx, rx) = channel();
                let load = Arc::new(AtomicUsize::new(0));
                let load_t = load.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("dsde-replica-{i}"))
                    .spawn(move || replica_loop(engine, rx, load_t))
                    .expect("spawn replica thread");
                Replica {
                    tx,
                    load,
                    thread: Mutex::new(Some(thread)),
                }
            })
            .collect();
        EngineRouter {
            replicas,
            policy,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
        }
    }

    /// Number of engine replicas behind this router.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The dispatch policy in effect.
    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Current in-flight request count per replica.
    pub fn loads(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.load.load(Ordering::SeqCst))
            .collect()
    }

    /// Total in-flight requests across replicas.
    pub fn in_flight(&self) -> usize {
        self.loads().iter().sum()
    }

    /// Pick a replica index for the next request.
    fn pick(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::SeqCst) % self.replicas.len()
            }
            RoutePolicy::LeastLoaded => {
                let loads = self.loads();
                let mut best = 0usize;
                for (i, &l) in loads.iter().enumerate() {
                    if l < loads[best] {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Dispatch a request to a replica; returns the channel the finished
    /// result arrives on.  The router assigns globally unique request ids
    /// (any caller-provided id is overwritten).
    pub fn submit(&self, mut req: Request) -> Receiver<FinishedRequest> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let idx = self.pick();
        let replica = &self.replicas[idx];
        let (rtx, rrx) = channel();
        replica.load.fetch_add(1, Ordering::SeqCst);
        if replica.tx.send(EngineMsg::Submit(req, rtx)).is_err() {
            // replica already shut down; undo the load count — the caller
            // observes a closed reply channel
            replica.load.fetch_sub(1, Ordering::SeqCst);
        }
        rrx
    }

    /// Dispatch a request whose output is consumed incrementally: the
    /// returned channel yields one [`StreamEvent::Delta`] per engine step
    /// that accepted tokens for the request, then [`StreamEvent::Done`]
    /// with the finished-request summary, after which it closes.  Routing
    /// (policy, unique ids, load accounting) and drain semantics are
    /// identical to [`EngineRouter::submit`].
    pub fn submit_streaming(&self, mut req: Request) -> Receiver<StreamEvent> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let idx = self.pick();
        let replica = &self.replicas[idx];
        let (rtx, rrx) = channel();
        replica.load.fetch_add(1, Ordering::SeqCst);
        if replica
            .tx
            .send(EngineMsg::SubmitStreaming(req, rtx))
            .is_err()
        {
            replica.load.fetch_sub(1, Ordering::SeqCst);
        }
        rrx
    }

    /// Submit and block until the request completes.
    pub fn complete(&self, req: Request) -> Result<FinishedRequest> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("request dropped: router is shutting down"))
    }

    /// Per-replica metrics snapshots with the default percentile set
    /// (skips replicas that already exited).  Each reply is pre-reduced on
    /// the replica thread — O(#quantiles), never the full request window —
    /// so high-frequency scraping stays cheap.
    pub fn replica_metrics(&self) -> Vec<MetricsSnapshot> {
        self.replica_metrics_with(DEFAULT_QUANTILES)
    }

    /// Per-replica metrics snapshots carrying the requested percentiles.
    pub fn replica_metrics_with(&self, quantiles: &[f64]) -> Vec<MetricsSnapshot> {
        self.replicas
            .iter()
            .filter_map(|r| {
                let (tx, rx) = channel();
                r.tx.send(EngineMsg::Metrics(quantiles.to_vec(), tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Merge per-replica snapshots into one aggregate (counters summed,
    /// distributions merged exactly, percentiles taking the per-quantile
    /// maximum across replicas — see [`MetricsSnapshot::merge`]).
    fn merge_snapshots(per: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut iter = per.iter();
        let Some(first) = iter.next() else {
            return MetricsSnapshot::default();
        };
        let mut agg = first.clone();
        for m in iter {
            agg.merge(m);
        }
        agg
    }

    /// Metrics aggregated across all live replicas.
    pub fn aggregated_metrics(&self) -> MetricsSnapshot {
        Self::merge_snapshots(&self.replica_metrics())
    }

    /// The `/v1/metrics` payload: aggregate counters plus a per-replica
    /// summary and the routing configuration.
    ///
    /// The merged `throughput`/`goodput` divide by *summed* busy seconds
    /// (per-busy-second rates, flat in replica count); `fleet_throughput`
    /// divides total tokens by the fleet makespan (the slowest replica's
    /// busy time) and is the number that scales with replicas.
    pub fn metrics_json(&self) -> Json {
        let per = self.replica_metrics();
        let agg = Self::merge_snapshots(&per);
        let makespan = per.iter().map(|m| m.busy_time).fold(0.0f64, f64::max);
        let fleet_throughput = if makespan > 0.0 {
            agg.tokens_out as f64 / makespan
        } else {
            0.0
        };
        let loads = self.loads();
        let replicas: Vec<Json> = per
            .iter()
            .enumerate()
            .map(|(i, m)| {
                Json::obj()
                    .set("replica", i)
                    .set("in_flight", *loads.get(i).unwrap_or(&0))
                    .set("tokens_out", m.tokens_out)
                    .set("requests", m.completed)
                    .set("throughput", m.throughput())
                    .set("busy_time", m.busy_time)
                    .set("preemptions", m.preemptions)
            })
            .collect();
        agg.to_json()
            .set("route_policy", self.policy.name())
            .set("replica_count", self.replicas.len())
            .set("fleet_makespan", makespan)
            .set("fleet_throughput", fleet_throughput)
            .set("replicas", replicas)
    }

    /// Graceful drain: every replica finishes its in-flight work (clients
    /// receive their completions), then the threads exit.  Idempotent.
    pub fn shutdown(&self) {
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Drain);
        }
        self.join();
    }

    /// Hard stop: in-flight work is aborted (`FinishReason::Aborted`).
    pub fn abort(&self) {
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Abort);
        }
        self.join();
    }

    fn join(&self) {
        for r in &self.replicas {
            let handle = r.thread.lock().expect("replica lock").take();
            if let Some(t) = handle {
                let _ = t.join();
            }
        }
    }
}

impl Drop for EngineRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::engine::request::{FinishReason, SamplingParams};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engines(n: usize) -> Vec<Engine> {
        (0..n)
            .map(|i| {
                let cfg = EngineConfig {
                    max_batch: 4,
                    max_len: 4096,
                    policy: SlPolicyKind::Static(4),
                    seed: 10 + i as u64,
                    ..Default::default()
                };
                let model = SimModel::new(
                    SimPairKind::LlamaLike,
                    DatasetProfile::cnndm(),
                    10 + i as u64,
                );
                Engine::new(cfg, Box::new(model))
            })
            .collect()
    }

    fn req(max_tokens: usize) -> Request {
        Request::new(
            0,
            vec![65; 24],
            SamplingParams {
                max_tokens,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_replica_roundtrip() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        router.shutdown();
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let router = EngineRouter::new(sim_engines(3), RoutePolicy::RoundRobin);
        assert_eq!(router.pick(), 0);
        assert_eq!(router.pick(), 1);
        assert_eq!(router.pick(), 2);
        assert_eq!(router.pick(), 0);
        router.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        // manufacture imbalance: replica 0 busy with 3 in-flight
        router.replicas[0].load.store(3, Ordering::SeqCst);
        assert_eq!(router.pick(), 1);
        router.replicas[0].load.store(0, Ordering::SeqCst);
        router.shutdown();
    }

    #[test]
    fn ids_are_globally_unique_across_replicas() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..10).map(|_| router.submit(req(4))).collect();
        let mut ids: Vec<u64> = rxs.into_iter().map(|rx| rx.recv().unwrap().id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        router.shutdown();
    }

    #[test]
    fn graceful_shutdown_completes_in_flight_work() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..6).map(|_| router.submit(req(32))).collect();
        router.shutdown(); // drain: all six must still complete normally
        for rx in rxs {
            let fin = rx.recv().expect("drained request must complete");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 32);
        }
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn abort_delivers_aborted_results() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..3).map(|_| router.submit(req(100_000))).collect();
        router.abort();
        for rx in rxs {
            let fin = rx.recv().expect("aborted request still resolves");
            assert_eq!(fin.reason, FinishReason::Aborted);
        }
    }

    #[test]
    fn unfittable_prompt_is_aborted_and_replica_stays_alive() {
        // KV capacity: 8 blocks * 16 tokens = 128 slots; a 200-token prompt
        // can never be admitted.  The replica must abort it (not busy-spin)
        // and keep serving subsequent requests.
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            kv_blocks: 8,
            policy: SlPolicyKind::Static(4),
            seed: 5,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 5);
        let router = EngineRouter::new(
            vec![Engine::new(cfg, Box::new(model))],
            RoutePolicy::RoundRobin,
        );
        // queue a servable request BEHIND the poison head before the
        // replica reacts: only the head may be aborted, not its followers
        let poisoned_rx =
            router.submit(Request::new(0, vec![65; 200], SamplingParams::default()));
        let behind_rx = router.submit(req(8));
        let poisoned = poisoned_rx.recv().expect("wedged request must resolve");
        assert_eq!(poisoned.reason, FinishReason::Aborted);
        let behind = behind_rx.recv().expect("follower must survive the abort");
        assert_eq!(behind.reason, FinishReason::MaxTokens);
        assert_eq!(behind.output.len(), 8);
        // the replica is unwedged and serves fresh traffic too
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn streaming_deltas_concatenate_to_full_output() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rx = router.submit_streaming(req(16));
        let mut tokens = Vec::new();
        let mut last_t = f64::NEG_INFINITY;
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Delta { tokens: t, t: at } => {
                    assert!(at >= last_t, "deltas must arrive in clock order");
                    assert!(!t.is_empty());
                    last_t = at;
                    tokens.extend(t);
                }
                StreamEvent::Done(fin) => done = Some(fin),
            }
        }
        // the channel closed right after the terminal event
        let fin = done.expect("stream must end with Done");
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(tokens, fin.output, "deltas must concatenate to the output");
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn streaming_subscriber_hangup_does_not_wedge_replica() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        drop(router.submit_streaming(req(64))); // client vanished immediately
        // the replica keeps serving fresh traffic and load drains to zero
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        router.shutdown();
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn submit_after_shutdown_errors_cleanly() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        router.shutdown();
        assert!(router.complete(req(4)).is_err());
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn aggregated_metrics_sum_replica_counters() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..8).map(|_| router.submit(req(12))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let per = router.replica_metrics();
        assert_eq!(per.len(), 2);
        let agg = router.aggregated_metrics();
        assert_eq!(
            agg.tokens_out,
            per.iter().map(|m| m.tokens_out).sum::<u64>()
        );
        assert_eq!(agg.completed, 8);
        // round-robin with blocking-free submission: both replicas worked
        assert!(per.iter().all(|m| m.completed == 4));
        router.shutdown();
    }

    #[test]
    fn metrics_json_has_aggregate_and_per_replica_views() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        let fin = router.complete(req(6)).unwrap();
        assert_eq!(fin.output.len(), 6);
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"replica_count\":2"), "{s}");
        assert!(s.contains("\"route_policy\":\"least-loaded\""), "{s}");
        assert!(s.contains("\"replicas\":["), "{s}");
        assert!(s.contains("block_efficiency"), "{s}");
        router.shutdown();
    }
}
