//! Multi-replica engine router: horizontal scale for the serving stack.
//!
//! An [`EngineRouter`] owns N engine replicas — each with its own model
//! instance, KV cache, scheduler, and dedicated thread running the staged
//! `plan → execute → apply` loop — and dispatches requests to them by a
//! pluggable [`RoutePolicy`] (round-robin or least-loaded by in-flight
//! count).  It aggregates [`MetricsSnapshot`]s across replicas for
//! `/v1/metrics` and performs a graceful drain on shutdown: every replica
//! finishes its in-flight batch before its thread exits.
//!
//! Requests can complete two ways:
//! * [`EngineRouter::submit`] / [`EngineRouter::complete`] — one
//!   [`FinishedRequest`] when the whole output exists;
//! * [`EngineRouter::submit_streaming`] — a [`StreamEvent`] channel that
//!   carries every accepted-token delta as the engine's step loop applies
//!   it ([`StreamEvent::Delta`]), then the finished-request summary
//!   ([`StreamEvent::Done`]); the channel closes after the terminal event.
//!   Drain still delivers every delta and the terminal event; abort
//!   terminates open streams with a `FinishReason::Aborted` summary.
//!
//! Replicas are share-nothing for *execution*: no KV or signal state
//! crosses the boundary, so aggregate throughput scales with replica count
//! until the host runs out of cores (see `benches/serving_load.rs`).  Two
//! placement layers do look across the boundary:
//!
//! * **KV-aware routing** ([`RoutePolicy::KvAware`]): each replica thread
//!   publishes a [`ReplicaLoad`] snapshot (KV occupancy + queue pressure)
//!   into a lock-free load cell after every step; `submit` picks the
//!   replica with the most projected KV-block headroom for the candidate
//!   request (prompt + output budget), instead of the fewest in-flight
//!   requests.  Request counts are blind to sequence length; blocks are
//!   the resource that actually saturates.
//! * **Work stealing** ([`EngineRouter::with_options`]): a balancer thread
//!   watches the load cells; when a replica goes idle while a sibling
//!   still has ≥2 queued (not in-flight) requests, it migrates untouched
//!   queued requests — with their reply channels — to the idle replica,
//!   fixing the drain-tail imbalance.  Only never-run sequences migrate,
//!   so placement can never change a request's output tokens.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::RoutePolicy;
use crate::engine::engine::{Engine, ReplicaLoad, StepOutcome};
use crate::engine::metrics::{MetricsSnapshot, DEFAULT_QUANTILES};
use crate::engine::request::{FinishedRequest, Request};
use crate::engine::step::StepReport;
use crate::util::json::Json;
use crate::util::spsc;
use crate::util::sys::Waker;
use crate::log_warn;

use super::conn::{stream_delta_frame, stream_done_frame};

/// Hook invoked with every routed request right after its router-global
/// id is assigned and before it is dispatched to a replica — the serving
/// stack's trace-record point (`--record`; see
/// [`crate::eval::trace::TraceRecorder`]).  Fires on the submitting
/// thread, so implementations should stay cheap (the trace recorder does
/// one buffered line write).
pub type RecordHook = Box<dyn Fn(&Request) + Send + Sync>;

/// One event on a streaming request's channel.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Tokens accepted for this request in one engine step.
    Delta {
        /// The accepted tokens, in generation order.
        tokens: Vec<u32>,
        /// Engine-clock time the tokens were applied at.
        t: f64,
    },
    /// Terminal event: the completed request summary.  The channel closes
    /// after this is delivered.
    Done(FinishedRequest),
}

/// A reply sender plus the optional event-loop waker poked after every
/// successful send.  This is the nonblocking notification path of the
/// poll-based front-end: the replica thread delivers on the plain mpsc
/// channel exactly as before, then pokes the waker so the event loop
/// wakes and `try_recv`s — no blocking `recv` anywhere on the loop.  The
/// threaded front-end passes no waker and the wrapper is free.  Waker
/// pokes coalesce inside [`Waker::wake`] (an atomic wake-pending flag),
/// so a burst of deliveries between two loop iterations costs one
/// eventfd/pipe write, not one per delivery.
pub(crate) struct Notify<T> {
    tx: Sender<T>,
    waker: Option<Arc<Waker>>,
}

impl<T> Notify<T> {
    fn new(tx: Sender<T>, waker: Option<Arc<Waker>>) -> Notify<T> {
        Notify { tx, waker }
    }

    fn send(&self, v: T) -> Result<(), std::sync::mpsc::SendError<T>> {
        let r = self.tx.send(v);
        if r.is_ok() {
            if let Some(w) = &self.waker {
                w.wake();
            }
        }
        r
    }
}

/// Per-(replica, shard) SPSC ring capacity in frames.  Deep enough that a
/// full ring means the shard loop has not run for hundreds of deliveries;
/// overflow then spills to the replica-local queue (see [`ShardTx`])
/// rather than blocking the engine or dropping frames.
pub(crate) const STREAM_RING_CAP: usize = 1024;

/// One preformatted NDJSON stream frame bound for an event-loop shard:
/// the bytes are already chunk-encoded on the replica thread, so the
/// shard loop appends them straight to the connection's output buffer.
pub(crate) struct StreamFrame {
    /// Event-loop connection token the frame belongs to (frames whose
    /// connection has closed are discarded by the shard loop).
    pub(crate) conn: u64,
    /// Wire bytes, ready to append to the connection's out buffer.
    pub(crate) bytes: Vec<u8>,
    /// Terminal frame: carries the done summary plus the chunked-encoding
    /// terminator; the stream is complete once these bytes flush.
    pub(crate) done: bool,
}

/// Where a ring-delivered stream's frames go: which loop shard consumes
/// them and which connection (by token) they belong to.  Replica-neutral,
/// so work stealing migrates ring streams like any other reply channel —
/// every replica holds a producer to every shard.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RingTarget {
    /// Index of the event-loop shard that owns the connection.
    pub(crate) shard: usize,
    /// The connection's loop-assigned token.
    pub(crate) conn: u64,
}

/// A replica's producer endpoint for one event-loop shard: the SPSC ring,
/// the shard's waker (pokes coalesce in [`Waker::wake`]), and a
/// replica-local overflow queue.
///
/// A full ring normally backpressures the producer — but a replica thread
/// must never *block* on a shard loop, because the loop itself can block
/// on the replica (a `/v1/metrics` dispatch does a synchronous metrics
/// round-trip); parking here could deadlock the pair.  So a frame that
/// cannot enter the ring is parked in `overflow` (unbounded, exactly the
/// delivery guarantee the old per-request mpsc channels gave) and retried
/// on every subsequent send and once per replica-loop iteration.  Frames
/// are never dropped while the consumer lives; a dropped consumer (shard
/// loop exited) discards them, matching the old hung-up-subscriber
/// semantics.
pub(crate) struct ShardTx {
    tx: spsc::Producer<StreamFrame>,
    waker: Arc<Waker>,
    overflow: VecDeque<StreamFrame>,
}

impl ShardTx {
    /// Wrap a ring producer and the owning shard's waker.
    pub(crate) fn new(tx: spsc::Producer<StreamFrame>, waker: Arc<Waker>) -> ShardTx {
        ShardTx {
            tx,
            waker,
            overflow: VecDeque::new(),
        }
    }

    /// Retry delivery of parked frames (oldest first, preserving order).
    /// Returns true when nothing remains to deliver — the overflow is
    /// empty, or the consumer is gone and the backlog was discarded.
    fn pump(&mut self) -> bool {
        if self.tx.is_closed() {
            self.overflow.clear();
            return true;
        }
        let mut pushed = false;
        while let Some(frame) = self.overflow.pop_front() {
            match self.tx.try_push(frame) {
                Ok(()) => pushed = true,
                Err(spsc::PushError::Full(f)) => {
                    self.overflow.push_front(f);
                    break;
                }
                Err(spsc::PushError::Closed(_)) => {
                    self.overflow.clear();
                    return true;
                }
            }
        }
        if pushed {
            self.waker.wake();
        }
        self.overflow.is_empty()
    }

    /// Queue one frame for the shard, preserving per-connection order:
    /// ring first, replica-local overflow when the ring is full.
    fn send(&mut self, frame: StreamFrame) {
        if self.tx.is_closed() {
            self.overflow.clear();
            return;
        }
        self.pump();
        if !self.overflow.is_empty() {
            self.overflow.push_back(frame);
            return;
        }
        match self.tx.try_push(frame) {
            Ok(()) => self.waker.wake(),
            Err(spsc::PushError::Full(f)) => {
                self.overflow.push_back(f);
                // the ring has frames regardless; make sure the shard is
                // awake to drain them
                self.waker.wake();
            }
            Err(spsc::PushError::Closed(_)) => {}
        }
    }

    /// Whether parked frames are waiting for ring space.
    fn has_backlog(&self) -> bool {
        !self.overflow.is_empty()
    }
}

/// Retry every shard's parked frames; true when all are delivered (or
/// discarded because their consumer is gone).
fn pump_shards(shards: &mut [ShardTx]) -> bool {
    let mut all = true;
    for s in shards.iter_mut() {
        if !s.pump() {
            all = false;
        }
    }
    all
}

/// Block (politely) until every parked frame is delivered or its consumer
/// is gone — the replica-exit path, so terminal frames written during
/// drain/abort cannot be lost with the thread.
fn flush_shards_before_exit(shards: &mut [ShardTx]) {
    while !pump_shards(shards) {
        std::thread::sleep(Duration::from_micros(100));
    }
}

/// The reply channel of a request in flight on a replica — shipped along
/// with the request when the balancer migrates it to another replica, so
/// stealing is invisible to the waiting client.
pub(crate) enum ReplyTo {
    /// Blocking submitter waiting for the one [`FinishedRequest`].
    Blocking(Notify<FinishedRequest>),
    /// Streaming subscriber consuming [`StreamEvent`]s.
    Streaming(Notify<StreamEvent>),
    /// Event-loop stream delivered as preformatted frames on the target
    /// shard's ring.  Replica-independent, so it migrates freely.
    Ring(RingTarget),
}

/// Messages into a replica's engine thread.
pub(crate) enum EngineMsg {
    /// Submit a request; the finished result is sent on the reply channel.
    Submit(Request, Notify<FinishedRequest>),
    /// Submit a request whose per-step token deltas (and terminal summary)
    /// are forwarded on the reply channel as they happen.
    SubmitStreaming(Request, Notify<StreamEvent>),
    /// Submit a request whose deltas are chunk-encoded on this thread and
    /// pushed to the target shard's SPSC ring (the event-loop streaming
    /// path; see [`StreamFrame`]).
    SubmitStreamingRing(Request, RingTarget),
    /// Install this replica's per-shard ring producers.  Sent once per
    /// replica before the front-end starts accepting, so channel FIFO
    /// guarantees it precedes every `SubmitStreamingRing`.
    AttachShards(Vec<ShardTx>),
    /// Work stealing, victim side: migrate up to `max` untouched waiting
    /// requests (with their reply channels) back to the balancer.  Replies
    /// with an empty batch when nothing is stealable.
    Steal(usize, Sender<Vec<(Request, ReplyTo)>>),
    /// Work stealing, thief side: adopt migrated requests, re-registering
    /// their reply channels.
    SubmitStolen(Vec<(Request, ReplyTo)>),
    /// Snapshot this replica's metrics, pre-reduced to scalars plus the
    /// requested percentiles (never the full retained request window).
    Metrics(Vec<f64>, Sender<MetricsSnapshot>),
    /// Graceful drain: finish everything in flight, then exit the thread.
    Drain,
    /// Abort in-flight work (clients observe `FinishReason::Aborted`) and
    /// exit the thread.
    Abort,
}

/// Projected token demand of a request: its prompt plus the full output
/// budget it may grow to — the KV footprint placement must plan for.
fn projected_tokens(req: &Request) -> usize {
    req.prompt.len() + req.params.max_tokens
}

/// Lock-free per-replica load gauges shared between the replica thread
/// (publisher), the router's submit path (KV-aware pick), and the balancer
/// (steal trigger).  Staleness is bounded by one engine step; the
/// `channel_*` pair covers the gap between a submit and the replica's next
/// intake, so a burst of submissions is visible to placement immediately.
pub(crate) struct LoadCell {
    /// Tokens per KV block (immutable; set at construction).
    block_size: usize,
    /// Sequences currently scheduled in the running batch.
    in_flight: AtomicUsize,
    /// KV blocks currently mapped.
    kv_used_blocks: AtomicUsize,
    /// KV blocks currently free.
    kv_free_blocks: AtomicUsize,
    /// Requests waiting in the engine's admission queue.
    queued_requests: AtomicUsize,
    /// Projected token demand of the engine's waiting queue.
    queued_prompt_tokens: AtomicUsize,
    /// Requests sent to the replica's channel but not yet taken in
    /// (router/balancer adds, replica subtracts on intake).
    channel_requests: AtomicUsize,
    /// Projected token demand of the channel backlog.
    channel_tokens: AtomicUsize,
}

impl LoadCell {
    fn new(engine: &Engine) -> LoadCell {
        let snap = engine.load_snapshot();
        LoadCell {
            block_size: engine.kv_block_size(),
            in_flight: AtomicUsize::new(snap.in_flight),
            kv_used_blocks: AtomicUsize::new(snap.kv_used_blocks),
            kv_free_blocks: AtomicUsize::new(snap.kv_free_blocks),
            queued_requests: AtomicUsize::new(snap.queued_requests),
            queued_prompt_tokens: AtomicUsize::new(snap.queued_prompt_tokens),
            channel_requests: AtomicUsize::new(0),
            channel_tokens: AtomicUsize::new(0),
        }
    }

    /// Replica thread: publish fresh engine-truth gauges.
    fn publish(&self, snap: &ReplicaLoad) {
        self.in_flight.store(snap.in_flight, Ordering::SeqCst);
        self.kv_used_blocks.store(snap.kv_used_blocks, Ordering::SeqCst);
        self.kv_free_blocks.store(snap.kv_free_blocks, Ordering::SeqCst);
        self.queued_requests.store(snap.queued_requests, Ordering::SeqCst);
        self.queued_prompt_tokens
            .store(snap.queued_prompt_tokens, Ordering::SeqCst);
    }

    /// Router/balancer: a request was sent to the replica's channel.
    fn on_enqueue(&self, req: &Request) {
        self.channel_requests.fetch_add(1, Ordering::SeqCst);
        self.channel_tokens
            .fetch_add(projected_tokens(req), Ordering::SeqCst);
    }

    /// Undo [`LoadCell::on_enqueue`] (failed send, or replica intake).
    fn on_dequeue(&self, req: &Request) {
        self.channel_requests.fetch_sub(1, Ordering::SeqCst);
        self.channel_tokens
            .fetch_sub(projected_tokens(req), Ordering::SeqCst);
    }

    /// Queue depth the balancer sees: engine waiting + channel backlog.
    fn queued_total(&self) -> usize {
        self.queued_requests.load(Ordering::SeqCst)
            + self.channel_requests.load(Ordering::SeqCst)
    }

    /// Projected free blocks after this replica absorbs its queued work,
    /// channel backlog, and the candidate request.  Negative = projected
    /// KV over-subscription (preemption thrash ahead).
    fn kv_headroom(&self, candidate_tokens: usize) -> isize {
        let free = self.kv_free_blocks.load(Ordering::SeqCst) as isize;
        let backlog = self.queued_prompt_tokens.load(Ordering::SeqCst)
            + self.channel_tokens.load(Ordering::SeqCst)
            + candidate_tokens;
        free - backlog.div_ceil(self.block_size) as isize
    }

    /// Snapshot the published gauges (channel backlog folded into the
    /// queue fields so callers see the router-wide truth).
    fn snapshot(&self) -> ReplicaLoad {
        ReplicaLoad {
            in_flight: self.in_flight.load(Ordering::SeqCst),
            kv_used_blocks: self.kv_used_blocks.load(Ordering::SeqCst),
            kv_free_blocks: self.kv_free_blocks.load(Ordering::SeqCst),
            queued_requests: self.queued_total(),
            queued_prompt_tokens: self.queued_prompt_tokens.load(Ordering::SeqCst)
                + self.channel_tokens.load(Ordering::SeqCst),
        }
    }
}

/// One engine replica: channel + thread + in-flight counter + load gauges.
struct Replica {
    tx: Sender<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

/// Deliver finished requests to their waiting reply channels — blocking
/// submitters get the [`FinishedRequest`], streaming subscribers get the
/// terminal [`StreamEvent::Done`] (which also closes their channel), and
/// ring streams get a terminal [`StreamFrame`] carrying the done summary
/// plus the chunked-encoding terminator.
fn deliver(
    engine: &mut Engine,
    pending: &mut HashMap<u64, Notify<FinishedRequest>>,
    streams: &mut HashMap<u64, Notify<StreamEvent>>,
    ring_streams: &mut HashMap<u64, RingTarget>,
    shards: &mut [ShardTx],
    load: &AtomicUsize,
) {
    for fin in engine.take_finished() {
        load.fetch_sub(1, Ordering::SeqCst);
        if let Some(reply) = pending.remove(&fin.id) {
            let _ = reply.send(fin);
        } else if let Some(reply) = streams.remove(&fin.id) {
            let _ = reply.send(StreamEvent::Done(fin));
        } else if let Some(target) = ring_streams.remove(&fin.id) {
            if let Some(shard) = shards.get_mut(target.shard) {
                shard.send(StreamFrame {
                    conn: target.conn,
                    bytes: stream_done_frame(&fin),
                    done: true,
                });
            }
        }
    }
    // orphaned waiters (should not happen): drop their channels so callers
    // error out instead of hanging — and release their load slots so
    // least-loaded routing does not shun this replica forever
    if engine.pending() == 0
        && (!pending.is_empty() || !streams.is_empty() || !ring_streams.is_empty())
    {
        load.fetch_sub(
            pending.len() + streams.len() + ring_streams.len(),
            Ordering::SeqCst,
        );
        pending.clear();
        streams.clear();
        ring_streams.clear();
    }
}

/// Forward one step's accepted-token deltas to their streaming
/// subscribers.  Takes the report by value so the token vectors move into
/// the channel instead of being cloned on the per-step hot path.  A
/// hung-up subscriber is dropped from the map — its request still runs to
/// completion and is accounted normally; only the forwarding stops.  Ring
/// streams are chunk-encoded here, on the replica thread, so the shard
/// loop only ever appends ready-made bytes.
fn forward_deltas(
    report: StepReport,
    streams: &mut HashMap<u64, Notify<StreamEvent>>,
    ring_streams: &HashMap<u64, RingTarget>,
    shards: &mut [ShardTx],
) {
    for d in report.deltas {
        if let Some(&target) = ring_streams.get(&d.id) {
            if let Some(shard) = shards.get_mut(target.shard) {
                shard.send(StreamFrame {
                    conn: target.conn,
                    bytes: stream_delta_frame(&d.tokens, d.t),
                    done: false,
                });
            }
            continue;
        }
        let dead = match streams.get(&d.id) {
            Some(tx) => tx
                .send(StreamEvent::Delta {
                    tokens: d.tokens,
                    t: d.t,
                })
                .is_err(),
            None => false,
        };
        if dead {
            streams.remove(&d.id);
        }
    }
}

/// A replica's engine thread: interleave request intake with engine steps
/// so new arrivals join the continuous batch.  Publishes fresh load gauges
/// into `cell` after every intake round and every step, so the router's
/// KV-aware pick and the balancer's steal trigger see at-most-one-step-old
/// truth.
fn replica_loop(
    mut engine: Engine,
    rx: Receiver<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
) {
    let mut pending: HashMap<u64, Notify<FinishedRequest>> = HashMap::new();
    let mut streams: HashMap<u64, Notify<StreamEvent>> = HashMap::new();
    let mut ring_streams: HashMap<u64, RingTarget> = HashMap::new();
    let mut shards: Vec<ShardTx> = Vec::new();
    let mut draining = false;
    let mut consecutive_errors = 0u32;
    loop {
        // drain the message queue (blocking when idle, else non-blocking)
        let mut took_msg = false;
        loop {
            let idle = engine.pending() == 0
                && pending.is_empty()
                && streams.is_empty()
                && ring_streams.is_empty()
                && !shards.iter().any(|s| s.has_backlog())
                && !draining;
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return, // router dropped: nothing in flight
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true; // router gone: finish what we hold
                        break;
                    }
                }
            };
            match msg {
                EngineMsg::Submit(req, reply) => {
                    cell.on_dequeue(&req);
                    pending.insert(req.id, reply);
                    engine.submit(req);
                }
                EngineMsg::SubmitStreaming(req, reply) => {
                    cell.on_dequeue(&req);
                    streams.insert(req.id, reply);
                    engine.submit(req);
                }
                EngineMsg::SubmitStreamingRing(req, target) => {
                    cell.on_dequeue(&req);
                    ring_streams.insert(req.id, target);
                    engine.submit(req);
                }
                EngineMsg::AttachShards(s) => {
                    shards = s;
                }
                EngineMsg::SubmitStolen(batch) => {
                    for (req, reply) in batch {
                        cell.on_dequeue(&req);
                        match reply {
                            ReplyTo::Blocking(tx) => {
                                pending.insert(req.id, tx);
                            }
                            ReplyTo::Streaming(tx) => {
                                streams.insert(req.id, tx);
                            }
                            ReplyTo::Ring(target) => {
                                ring_streams.insert(req.id, target);
                            }
                        }
                        engine.submit(req);
                    }
                }
                EngineMsg::Steal(max, reply) => {
                    let mut batch: Vec<(Request, ReplyTo)> = Vec::new();
                    for req in engine.steal_waiting(max) {
                        let rt = if let Some(tx) = pending.remove(&req.id) {
                            ReplyTo::Blocking(tx)
                        } else if let Some(tx) = streams.remove(&req.id) {
                            ReplyTo::Streaming(tx)
                        } else if let Some(target) = ring_streams.remove(&req.id) {
                            ReplyTo::Ring(target)
                        } else {
                            // no registered waiter (should not happen):
                            // keep the request local rather than lose it
                            engine.submit(req);
                            continue;
                        };
                        batch.push((req, rt));
                    }
                    if let Err(std::sync::mpsc::SendError(batch)) = reply.send(batch)
                    {
                        // balancer vanished mid-steal: nothing may be lost —
                        // restore the waiters and keep the work local
                        for (req, rt) in batch {
                            match rt {
                                ReplyTo::Blocking(tx) => {
                                    pending.insert(req.id, tx);
                                }
                                ReplyTo::Streaming(tx) => {
                                    streams.insert(req.id, tx);
                                }
                                ReplyTo::Ring(target) => {
                                    ring_streams.insert(req.id, target);
                                }
                            }
                            engine.submit(req);
                        }
                    }
                }
                EngineMsg::Metrics(quantiles, reply) => {
                    let _ = reply.send(engine.metrics.snapshot(&quantiles));
                }
                EngineMsg::Drain => draining = true,
                EngineMsg::Abort => {
                    engine.abort_all();
                    deliver(
                        &mut engine,
                        &mut pending,
                        &mut streams,
                        &mut ring_streams,
                        &mut shards,
                        &load,
                    );
                    cell.publish(&engine.load_snapshot());
                    flush_shards_before_exit(&mut shards);
                    return;
                }
            }
            took_msg = true;
        }
        if took_msg {
            // intake changed the queue; refresh the gauges before stepping
            cell.publish(&engine.load_snapshot());
        }
        if engine.pending() > 0 {
            // the report's post-step snapshot doubles as the publish, so
            // the normal path pays the O(#waiting) scan only once (in
            // apply); abnormal paths below re-snapshot explicitly
            let mut published = false;
            let progressed = match engine.step_detailed() {
                Ok(outcome) => {
                    consecutive_errors = 0;
                    match outcome {
                        StepOutcome::Idle => false,
                        StepOutcome::Retry => true,
                        StepOutcome::Ran(report) => {
                            cell.publish(&report.load);
                            published = true;
                            forward_deltas(
                                report,
                                &mut streams,
                                &ring_streams,
                                &mut shards,
                            );
                            true
                        }
                    }
                }
                Err(e) => {
                    consecutive_errors += 1;
                    log_warn!(
                        "engine step error ({consecutive_errors} consecutive): {e:#}"
                    );
                    // a transient failure is worth retrying; a persistently
                    // failing model must not wedge the replica forever
                    consecutive_errors < 3
                }
            };
            deliver(
                &mut engine,
                &mut pending,
                &mut streams,
                &mut ring_streams,
                &mut shards,
                &load,
            );
            if !progressed && engine.pending() > 0 {
                // Stuck, not just slow.  Two causes, two remedies — either
                // way the replica stays up instead of busy-spinning and
                // starving everything routed here:
                if consecutive_errors >= 3 {
                    // persistently failing model: the whole batch is
                    // unservable; clients observe FinishReason::Aborted
                    log_warn!(
                        "model failing persistently; aborting {} request(s)",
                        engine.pending()
                    );
                    engine.abort_all();
                    consecutive_errors = 0;
                } else {
                    // head-of-line prompt that can never fit in KV (FCFS
                    // forbids skipping it): abort just the head so the
                    // servable requests queued behind it proceed
                    if let Some(id) = engine.abort_head() {
                        log_warn!(
                            "aborting unschedulable request {id} \
                             (prompt cannot fit in KV)"
                        );
                    }
                }
                deliver(
                    &mut engine,
                    &mut pending,
                    &mut streams,
                    &mut ring_streams,
                    &mut shards,
                    &load,
                );
                published = false; // aborts changed queue/KV state
            }
            if !published {
                cell.publish(&engine.load_snapshot());
            }
        } else if draining {
            // terminal frames may still be parked in shard overflow
            // queues; they must land (or their consumer must be gone)
            // before this thread — their only producer — exits
            flush_shards_before_exit(&mut shards);
            return;
        } else if shards.iter().any(|s| s.has_backlog()) {
            // engine idle but stream frames are parked waiting for ring
            // space: retry their delivery without busy-spinning
            if !pump_shards(&mut shards) {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
    }
}

/// How often the balancer re-examines the load cells while the fleet has
/// work in flight.  Cheap (a handful of atomic loads per replica), so it
/// can afford to be much finer than a round.
const STEAL_POLL: Duration = Duration::from_micros(200);

/// Balancer poll interval while the fleet is completely idle — no point
/// burning 5k wake-ups/second on a server at zero traffic.  Worst-case
/// added steal latency after an idle period is one of these.
const STEAL_POLL_IDLE: Duration = Duration::from_millis(2);

/// Minimum queued (not in-flight) requests on a replica before the
/// balancer migrates work off it: a queue of one is the FCFS head and is
/// about to run locally anyway.
const STEAL_MIN_QUEUE: usize = 2;

/// The balancer thread's per-replica handle (its own channel clone +
/// shared counters; the router's `Replica` structs stay single-owner).
struct BalancerView {
    tx: Sender<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
}

/// Work-stealing balancer: poll the load cells; when a replica sits idle
/// while a sibling has a queue, migrate untouched queued requests (and
/// their reply channels) from the deepest queue to the idle replicas.
/// Runs until the router stops it (always before drain/abort, so replica
/// threads are guaranteed alive and responsive here).
fn balancer_loop(
    views: Vec<BalancerView>,
    stop: Arc<AtomicBool>,
    steals: Arc<AtomicU64>,
) {
    while !stop.load(Ordering::SeqCst) {
        // fine-grained polling only while someone has work; idle fleets
        // back off so the thread costs ~nothing at zero traffic
        let busy = views
            .iter()
            .any(|v| v.load.load(Ordering::SeqCst) > 0);
        std::thread::sleep(if busy { STEAL_POLL } else { STEAL_POLL_IDLE });
        // idle replicas: nothing router-tracked at all (queued or running)
        let idle: Vec<usize> = views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.load.load(Ordering::SeqCst) == 0)
            .map(|(i, _)| i)
            .collect();
        if idle.is_empty() {
            continue;
        }
        // victim: the deepest queue (engine waiting + channel backlog)
        let Some((victim, depth)) = views
            .iter()
            .enumerate()
            .map(|(i, v)| (i, v.cell.queued_total()))
            .max_by_key(|&(_, q)| q)
        else {
            continue;
        };
        if depth < STEAL_MIN_QUEUE {
            continue;
        }
        // leave the victim its fair share of its own queue
        let take = depth.div_ceil(idle.len() + 1).max(1);
        for &thief in &idle {
            if thief == victim {
                continue;
            }
            let (btx, brx) = channel();
            if views[victim].tx.send(EngineMsg::Steal(take, btx)).is_err() {
                break;
            }
            let Ok(batch) = brx.recv() else { break };
            if batch.is_empty() {
                break; // nothing stealable (started seqs / head only)
            }
            let n = batch.len();
            // in-flight accounting and channel projection migrate with
            // the requests, so placement keeps seeing the truth
            views[victim].load.fetch_sub(n, Ordering::SeqCst);
            views[thief].load.fetch_add(n, Ordering::SeqCst);
            for (req, _) in &batch {
                views[thief].cell.on_enqueue(req);
            }
            if let Err(std::sync::mpsc::SendError(msg)) =
                views[thief].tx.send(EngineMsg::SubmitStolen(batch))
            {
                // thief thread gone (it panicked — teardown always stops
                // the balancer first): fully undo the thief-side
                // accounting, then hand the still-servable batch back to
                // the live victim so nothing is dropped
                let EngineMsg::SubmitStolen(batch) = msg else {
                    unreachable!("send returns the message it was given")
                };
                views[thief].load.fetch_sub(n, Ordering::SeqCst);
                for (req, _) in &batch {
                    views[thief].cell.on_dequeue(req);
                }
                views[victim].load.fetch_add(n, Ordering::SeqCst);
                for (req, _) in &batch {
                    views[victim].cell.on_enqueue(req);
                }
                if let Err(std::sync::mpsc::SendError(msg)) =
                    views[victim].tx.send(EngineMsg::SubmitStolen(batch))
                {
                    // victim died too: undo and let the dropped reply
                    // channels surface as errors at the callers
                    views[victim].load.fetch_sub(n, Ordering::SeqCst);
                    if let EngineMsg::SubmitStolen(batch) = msg {
                        for (req, _) in &batch {
                            views[victim].cell.on_dequeue(req);
                        }
                    }
                }
                break;
            }
            steals.fetch_add(n as u64, Ordering::SeqCst);
        }
    }
}

/// Routes requests across engine replicas; aggregates their metrics.
pub struct EngineRouter {
    replicas: Vec<Replica>,
    policy: RoutePolicy,
    steal: bool,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
    steals: Arc<AtomicU64>,
    balancer_stop: Arc<AtomicBool>,
    balancer: Mutex<Option<JoinHandle<()>>>,
    record: Option<RecordHook>,
}

impl EngineRouter {
    /// Spawn one serving thread per engine, work stealing disabled.
    /// Panics on an empty replica set (a router with nothing behind it
    /// cannot serve).
    pub fn new(engines: Vec<Engine>, policy: RoutePolicy) -> EngineRouter {
        EngineRouter::with_options(engines, policy, false)
    }

    /// Spawn one serving thread per engine; with `steal` a balancer thread
    /// also runs, migrating untouched queued requests from a backlogged
    /// replica to an idle one (the drain-tail fix).  Stealing never changes
    /// a request's output tokens — only never-run sequences migrate.
    /// Panics on an empty replica set.
    pub fn with_options(
        engines: Vec<Engine>,
        policy: RoutePolicy,
        steal: bool,
    ) -> EngineRouter {
        assert!(!engines.is_empty(), "EngineRouter needs >= 1 engine");
        // a single replica has nobody to steal from: record the EFFECTIVE
        // state so /health and stealing_enabled() never claim a balancer
        // that does not exist
        let steal = steal && engines.len() >= 2;
        let replicas: Vec<Replica> = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let (tx, rx) = channel();
                let load = Arc::new(AtomicUsize::new(0));
                let cell = Arc::new(LoadCell::new(&engine));
                let load_t = load.clone();
                let cell_t = cell.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("dsde-replica-{i}"))
                    .spawn(move || replica_loop(engine, rx, load_t, cell_t))
                    .expect("spawn replica thread");
                Replica {
                    tx,
                    load,
                    cell,
                    thread: Mutex::new(Some(thread)),
                }
            })
            .collect();
        let steals = Arc::new(AtomicU64::new(0));
        let balancer_stop = Arc::new(AtomicBool::new(false));
        let balancer = if steal {
            let views: Vec<BalancerView> = replicas
                .iter()
                .map(|r| BalancerView {
                    tx: r.tx.clone(),
                    load: r.load.clone(),
                    cell: r.cell.clone(),
                })
                .collect();
            let stop = balancer_stop.clone();
            let stolen = steals.clone();
            Some(
                std::thread::Builder::new()
                    .name("dsde-balancer".to_string())
                    .spawn(move || balancer_loop(views, stop, stolen))
                    .expect("spawn balancer thread"),
            )
        } else {
            None
        };
        EngineRouter {
            replicas,
            policy,
            steal,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            steals,
            balancer_stop,
            balancer: Mutex::new(balancer),
            record: None,
        }
    }

    /// Install the request-record hook (the `--record` trace path).  Must
    /// be called before the router starts serving; every subsequent
    /// submission — blocking or streaming, from any front-end — fires it
    /// once with the id-assigned request.
    pub fn set_record_hook(&mut self, hook: RecordHook) {
        self.record = Some(hook);
    }

    /// Whether a record hook is installed (surfaced on `/health` so an
    /// operator can tell a trace is being captured).
    pub fn recording(&self) -> bool {
        self.record.is_some()
    }

    /// Number of engine replicas behind this router.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The dispatch policy in effect.
    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Whether the work-stealing balancer is actually running (false on a
    /// single-replica router even when stealing was requested).
    pub fn stealing_enabled(&self) -> bool {
        self.steal
    }

    /// Requests migrated between replicas by the balancer so far.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::SeqCst)
    }

    /// Current in-flight request count per replica.
    pub fn loads(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.load.load(Ordering::SeqCst))
            .collect()
    }

    /// Per-replica load gauges (KV occupancy + queue pressure) as last
    /// published by the replica threads, with the channel backlog folded
    /// in — the data the KV-aware policy routes on.
    pub fn replica_loads(&self) -> Vec<ReplicaLoad> {
        self.replicas.iter().map(|r| r.cell.snapshot()).collect()
    }

    /// Total in-flight requests across replicas.
    pub fn in_flight(&self) -> usize {
        self.loads().iter().sum()
    }

    /// Pick a replica index for a request with the given projected token
    /// demand (prompt + output budget; only KvAware uses it).
    fn pick(&self, candidate_tokens: usize) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::SeqCst) % self.replicas.len()
            }
            RoutePolicy::LeastLoaded => {
                let loads = self.loads();
                let mut best = 0usize;
                for (i, &l) in loads.iter().enumerate() {
                    if l < loads[best] {
                        best = i;
                    }
                }
                best
            }
            RoutePolicy::KvAware => {
                let mut best = 0usize;
                let mut best_headroom = isize::MIN;
                let mut best_load = usize::MAX;
                for (i, r) in self.replicas.iter().enumerate() {
                    let headroom = r.cell.kv_headroom(candidate_tokens);
                    let load = r.load.load(Ordering::SeqCst);
                    // most projected KV headroom wins; in-flight count
                    // breaks ties (equal-KV replicas degrade to
                    // least-loaded, e.g. uniform workloads)
                    if headroom > best_headroom
                        || (headroom == best_headroom && load < best_load)
                    {
                        best = i;
                        best_headroom = headroom;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// Dispatch a request to a replica; returns the channel the finished
    /// result arrives on.  The router assigns globally unique request ids
    /// (any caller-provided id is overwritten).
    pub fn submit(&self, req: Request) -> Receiver<FinishedRequest> {
        let idx = self.pick(projected_tokens(&req));
        self.dispatch_to(idx, req, None)
    }

    /// Like [`EngineRouter::submit`], but the replica thread pokes `waker`
    /// after delivering the result — the event-loop front-end's
    /// nonblocking completion path (the loop `try_recv`s on wake instead
    /// of parking a thread in `recv`).
    pub fn submit_with_waker(
        &self,
        req: Request,
        waker: Arc<Waker>,
    ) -> Receiver<FinishedRequest> {
        let idx = self.pick(projected_tokens(&req));
        self.dispatch_to(idx, req, Some(waker))
    }

    /// Dispatch a request to a *specific* replica, bypassing the routing
    /// policy (ids are still router-assigned).  For diagnostics, benches,
    /// and imbalance tests — production traffic goes through
    /// [`EngineRouter::submit`].
    pub fn submit_to(&self, idx: usize, req: Request) -> Receiver<FinishedRequest> {
        self.dispatch_to(idx, req, None)
    }

    fn dispatch_to(
        &self,
        idx: usize,
        mut req: Request,
        waker: Option<Arc<Waker>>,
    ) -> Receiver<FinishedRequest> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let replica = &self.replicas[idx];
        let (rtx, rrx) = channel();
        replica.load.fetch_add(1, Ordering::SeqCst);
        replica.cell.on_enqueue(&req);
        if let Err(std::sync::mpsc::SendError(msg)) = replica
            .tx
            .send(EngineMsg::Submit(req, Notify::new(rtx, waker)))
        {
            // replica already shut down; undo the accounting — the caller
            // observes a closed reply channel
            replica.load.fetch_sub(1, Ordering::SeqCst);
            if let EngineMsg::Submit(req, _) = msg {
                replica.cell.on_dequeue(&req);
            }
        }
        rrx
    }

    /// Dispatch a request whose output is consumed incrementally: the
    /// returned channel yields one [`StreamEvent::Delta`] per engine step
    /// that accepted tokens for the request, then [`StreamEvent::Done`]
    /// with the finished-request summary, after which it closes.  Routing
    /// (policy, unique ids, load accounting) and drain semantics are
    /// identical to [`EngineRouter::submit`].
    pub fn submit_streaming(&self, req: Request) -> Receiver<StreamEvent> {
        self.submit_streaming_opts(req, None)
    }

    /// Like [`EngineRouter::submit_streaming`], but the replica thread
    /// pokes `waker` after every delta and after the terminal event — the
    /// event-loop front-end's nonblocking streaming path.
    pub fn submit_streaming_with_waker(
        &self,
        req: Request,
        waker: Arc<Waker>,
    ) -> Receiver<StreamEvent> {
        self.submit_streaming_opts(req, Some(waker))
    }

    fn submit_streaming_opts(
        &self,
        mut req: Request,
        waker: Option<Arc<Waker>>,
    ) -> Receiver<StreamEvent> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let idx = self.pick(projected_tokens(&req));
        let replica = &self.replicas[idx];
        let (rtx, rrx) = channel();
        replica.load.fetch_add(1, Ordering::SeqCst);
        replica.cell.on_enqueue(&req);
        if let Err(std::sync::mpsc::SendError(msg)) = replica
            .tx
            .send(EngineMsg::SubmitStreaming(req, Notify::new(rtx, waker)))
        {
            replica.load.fetch_sub(1, Ordering::SeqCst);
            if let EngineMsg::SubmitStreaming(req, _) = msg {
                replica.cell.on_dequeue(&req);
            }
        }
        rrx
    }

    /// Install each replica's per-shard ring producers (one [`ShardTx`]
    /// per event-loop shard, outer index = replica).  Must be called
    /// before the front-end starts accepting: the attach message travels
    /// the same FIFO channel as submissions, so every subsequent
    /// [`EngineRouter::submit_streaming_ring`] finds the rings in place.
    pub(crate) fn attach_stream_shards(&self, per_replica: Vec<Vec<ShardTx>>) {
        assert_eq!(
            per_replica.len(),
            self.replicas.len(),
            "one shard set per replica"
        );
        for (r, shards) in self.replicas.iter().zip(per_replica) {
            let _ = r.tx.send(EngineMsg::AttachShards(shards));
        }
    }

    /// Dispatch a streaming request whose deltas are delivered as
    /// preformatted NDJSON frames on `target`'s shard ring instead of an
    /// mpsc channel — the event-loop front-end's zero-channel streaming
    /// path.  Routing (policy, unique ids, load accounting, record hook)
    /// matches [`EngineRouter::submit_streaming`].  Returns false when
    /// the picked replica has already shut down (no frame will ever
    /// arrive; the caller writes the aborted summary itself).
    pub(crate) fn submit_streaming_ring(&self, mut req: Request, target: RingTarget) -> bool {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let idx = self.pick(projected_tokens(&req));
        let replica = &self.replicas[idx];
        replica.load.fetch_add(1, Ordering::SeqCst);
        replica.cell.on_enqueue(&req);
        if let Err(std::sync::mpsc::SendError(msg)) = replica
            .tx
            .send(EngineMsg::SubmitStreamingRing(req, target))
        {
            replica.load.fetch_sub(1, Ordering::SeqCst);
            if let EngineMsg::SubmitStreamingRing(req, _) = msg {
                replica.cell.on_dequeue(&req);
            }
            return false;
        }
        true
    }

    /// Submit and block until the request completes.
    pub fn complete(&self, req: Request) -> Result<FinishedRequest> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("request dropped: router is shutting down"))
    }

    /// Per-replica metrics snapshots with the default percentile set
    /// (skips replicas that already exited).  Each reply is pre-reduced on
    /// the replica thread — O(#quantiles), never the full request window —
    /// so high-frequency scraping stays cheap.
    pub fn replica_metrics(&self) -> Vec<MetricsSnapshot> {
        self.replica_metrics_with(DEFAULT_QUANTILES)
    }

    /// Per-replica metrics snapshots carrying the requested percentiles.
    pub fn replica_metrics_with(&self, quantiles: &[f64]) -> Vec<MetricsSnapshot> {
        self.replicas
            .iter()
            .filter_map(|r| {
                let (tx, rx) = channel();
                r.tx.send(EngineMsg::Metrics(quantiles.to_vec(), tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Merge per-replica snapshots into one aggregate (counters summed,
    /// distributions merged exactly, percentiles taking the per-quantile
    /// maximum across replicas — see [`MetricsSnapshot::merge`]).
    fn merge_snapshots(per: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut iter = per.iter();
        let Some(first) = iter.next() else {
            return MetricsSnapshot::default();
        };
        let mut agg = first.clone();
        for m in iter {
            agg.merge(m);
        }
        agg
    }

    /// Metrics aggregated across all live replicas.
    pub fn aggregated_metrics(&self) -> MetricsSnapshot {
        Self::merge_snapshots(&self.replica_metrics())
    }

    /// The `/v1/metrics` payload: aggregate counters plus a per-replica
    /// summary and the routing configuration.
    ///
    /// The merged `throughput`/`goodput` divide by *summed* busy seconds
    /// (per-busy-second rates, flat in replica count); `fleet_throughput`
    /// divides total tokens by the fleet makespan (the slowest replica's
    /// busy time) and is the number that scales with replicas.
    pub fn metrics_json(&self) -> Json {
        let per = self.replica_metrics();
        let agg = Self::merge_snapshots(&per);
        let makespan = per.iter().map(|m| m.busy_time).fold(0.0f64, f64::max);
        let fleet_throughput = if makespan > 0.0 {
            agg.tokens_out as f64 / makespan
        } else {
            0.0
        };
        let loads = self.loads();
        let cells = self.replica_loads();
        let replicas: Vec<Json> = per
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let lc = cells.get(i).copied().unwrap_or_default();
                Json::obj()
                    .set("replica", i)
                    .set("in_flight", *loads.get(i).unwrap_or(&0))
                    .set("tokens_out", m.tokens_out)
                    .set("requests", m.completed)
                    .set("throughput", m.throughput())
                    .set("busy_time", m.busy_time)
                    .set("preemptions", m.preemptions)
                    .set("kv_used_blocks", lc.kv_used_blocks)
                    .set("kv_free_blocks", lc.kv_free_blocks)
                    .set("queued_requests", lc.queued_requests)
                    .set("queued_prompt_tokens", lc.queued_prompt_tokens)
            })
            .collect();
        agg.to_json()
            .set("route_policy", self.policy.name())
            .set("replica_count", self.replicas.len())
            .set("work_stealing", self.steal)
            .set("steals", self.steals())
            .set("fleet_makespan", makespan)
            .set("fleet_throughput", fleet_throughput)
            .set("replicas", replicas)
    }

    /// Stop the balancer (if running) and wait for it — always before
    /// drain/abort so no steal can race a replica teardown.  Idempotent.
    fn stop_balancer(&self) {
        self.balancer_stop.store(true, Ordering::SeqCst);
        let handle = self.balancer.lock().expect("balancer lock").take();
        if let Some(t) = handle {
            let _ = t.join();
        }
    }

    /// Graceful drain: every replica finishes its in-flight work (clients
    /// receive their completions), then the threads exit.  Idempotent.
    pub fn shutdown(&self) {
        self.stop_balancer();
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Drain);
        }
        self.join();
    }

    /// Hard stop: in-flight work is aborted (`FinishReason::Aborted`).
    pub fn abort(&self) {
        self.stop_balancer();
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Abort);
        }
        self.join();
    }

    fn join(&self) {
        for r in &self.replicas {
            let handle = r.thread.lock().expect("replica lock").take();
            if let Some(t) = handle {
                let _ = t.join();
            }
        }
    }
}

impl Drop for EngineRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::engine::request::{FinishReason, SamplingParams};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engines(n: usize) -> Vec<Engine> {
        (0..n)
            .map(|i| {
                let cfg = EngineConfig {
                    max_batch: 4,
                    max_len: 4096,
                    policy: SlPolicyKind::Static(4),
                    seed: 10 + i as u64,
                    ..Default::default()
                };
                let model = SimModel::new(
                    SimPairKind::LlamaLike,
                    DatasetProfile::cnndm(),
                    10 + i as u64,
                );
                Engine::new(cfg, Box::new(model))
            })
            .collect()
    }

    fn req(max_tokens: usize) -> Request {
        Request::new(
            0,
            vec![65; 24],
            SamplingParams {
                max_tokens,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_replica_roundtrip() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        router.shutdown();
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let router = EngineRouter::new(sim_engines(3), RoutePolicy::RoundRobin);
        assert_eq!(router.pick(24), 0);
        assert_eq!(router.pick(24), 1);
        assert_eq!(router.pick(24), 2);
        assert_eq!(router.pick(24), 0);
        router.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        // manufacture imbalance: replica 0 busy with 3 in-flight
        router.replicas[0].load.store(3, Ordering::SeqCst);
        assert_eq!(router.pick(24), 1);
        router.replicas[0].load.store(0, Ordering::SeqCst);
        router.shutdown();
    }

    #[test]
    fn kv_aware_prefers_replica_with_block_headroom() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        // manufacture KV pressure on replica 0: almost no free blocks
        router.replicas[0]
            .cell
            .kv_free_blocks
            .store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        // flip it: replica 1 is the full one now
        router.replicas[0]
            .cell
            .kv_free_blocks
            .store(4096, Ordering::SeqCst);
        router.replicas[1]
            .cell
            .kv_free_blocks
            .store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 0);
        router.shutdown();
    }

    #[test]
    fn kv_aware_counts_queued_and_channel_backlog() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        // equal free blocks, but replica 0 has a deep projected queue
        router.replicas[0]
            .cell
            .queued_prompt_tokens
            .store(60_000, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        router.replicas[0]
            .cell
            .queued_prompt_tokens
            .store(0, Ordering::SeqCst);
        router.replicas[1]
            .cell
            .channel_tokens
            .store(60_000, Ordering::SeqCst);
        assert_eq!(router.pick(64), 0);
        router.replicas[1].cell.channel_tokens.store(0, Ordering::SeqCst);
        // all equal: tie breaks by in-flight count
        router.replicas[0].load.store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        router.replicas[0].load.store(0, Ordering::SeqCst);
        router.shutdown();
    }

    #[test]
    fn kv_aware_router_completes_everything() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        let rxs: Vec<_> = (0..10).map(|_| router.submit(req(8))).collect();
        for rx in rxs {
            let fin = rx.recv().expect("kv-aware routing must not drop work");
            assert_eq!(fin.output.len(), 8);
        }
        assert_eq!(router.in_flight(), 0);
        let agg = router.aggregated_metrics();
        assert_eq!(agg.completed, 10);
        router.shutdown();
    }

    #[test]
    fn submit_to_targets_specific_replica() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..4).map(|_| router.submit_to(1, req(6))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().output.len(), 6);
        }
        let per = router.replica_metrics();
        assert_eq!(per[0].completed, 0, "replica 0 must stay untouched");
        assert_eq!(per[1].completed, 4);
        router.shutdown();
    }

    #[test]
    fn work_stealing_rebalances_a_hot_replica() {
        // all work lands on replica 0; the balancer must move some of the
        // queue to idle replica 1, and nothing may be lost or duplicated.
        // Whether a steal fires in time is wall-clock dependent (the sim
        // burst races the 200µs balancer poll), so retry with fresh
        // routers; the no-loss/no-dup invariants are asserted every
        // attempt regardless.
        let n = 24;
        for attempt in 0..5 {
            let router = EngineRouter::with_options(
                sim_engines(2),
                RoutePolicy::RoundRobin,
                true,
            );
            let rxs: Vec<_> = (0..n).map(|_| router.submit_to(0, req(256))).collect();
            let mut ids = Vec::new();
            for rx in rxs {
                let fin = rx.recv().expect("stolen or local, every request resolves");
                assert_eq!(fin.reason, FinishReason::MaxTokens);
                assert_eq!(fin.output.len(), 256);
                ids.push(fin.id);
            }
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "no duplicate or lost completions");
            assert_eq!(router.in_flight(), 0);
            let stolen = router.steals();
            let per = router.replica_metrics();
            assert_eq!(per.iter().map(|m| m.completed).sum::<u64>(), n as u64);
            router.shutdown();
            if stolen > 0 {
                assert!(
                    per.iter().all(|m| m.completed > 0),
                    "both replicas must execute stolen work: {:?}",
                    per.iter().map(|m| m.completed).collect::<Vec<_>>()
                );
                return;
            }
            // burst drained before the balancer got scheduled; try again
            eprintln!("attempt {attempt}: no steal fired, retrying");
        }
        panic!("balancer never migrated work across 5 hot-replica bursts");
    }

    #[test]
    fn ids_are_globally_unique_across_replicas() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..10).map(|_| router.submit(req(4))).collect();
        let mut ids: Vec<u64> = rxs.into_iter().map(|rx| rx.recv().unwrap().id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        router.shutdown();
    }

    #[test]
    fn graceful_shutdown_completes_in_flight_work() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..6).map(|_| router.submit(req(32))).collect();
        router.shutdown(); // drain: all six must still complete normally
        for rx in rxs {
            let fin = rx.recv().expect("drained request must complete");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 32);
        }
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn abort_delivers_aborted_results() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..3).map(|_| router.submit(req(100_000))).collect();
        router.abort();
        for rx in rxs {
            let fin = rx.recv().expect("aborted request still resolves");
            assert_eq!(fin.reason, FinishReason::Aborted);
        }
    }

    #[test]
    fn unfittable_prompt_is_aborted_and_replica_stays_alive() {
        // KV capacity: 8 blocks * 16 tokens = 128 slots; a 200-token prompt
        // can never be admitted.  The replica must abort it (not busy-spin)
        // and keep serving subsequent requests.
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            kv_blocks: 8,
            policy: SlPolicyKind::Static(4),
            seed: 5,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 5);
        let router = EngineRouter::new(
            vec![Engine::new(cfg, Box::new(model))],
            RoutePolicy::RoundRobin,
        );
        // queue a servable request BEHIND the poison head before the
        // replica reacts: only the head may be aborted, not its followers
        let poisoned_rx =
            router.submit(Request::new(0, vec![65; 200], SamplingParams::default()));
        let behind_rx = router.submit(req(8));
        let poisoned = poisoned_rx.recv().expect("wedged request must resolve");
        assert_eq!(poisoned.reason, FinishReason::Aborted);
        let behind = behind_rx.recv().expect("follower must survive the abort");
        assert_eq!(behind.reason, FinishReason::MaxTokens);
        assert_eq!(behind.output.len(), 8);
        // the replica is unwedged and serves fresh traffic too
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn streaming_deltas_concatenate_to_full_output() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rx = router.submit_streaming(req(16));
        let mut tokens = Vec::new();
        let mut last_t = f64::NEG_INFINITY;
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Delta { tokens: t, t: at } => {
                    assert!(at >= last_t, "deltas must arrive in clock order");
                    assert!(!t.is_empty());
                    last_t = at;
                    tokens.extend(t);
                }
                StreamEvent::Done(fin) => done = Some(fin),
            }
        }
        // the channel closed right after the terminal event
        let fin = done.expect("stream must end with Done");
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(tokens, fin.output, "deltas must concatenate to the output");
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn ring_streaming_delivers_ordered_frames_with_terminal() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let (tx, mut rx) = spsc::ring(STREAM_RING_CAP);
        let waker = Arc::new(Waker::new().expect("waker"));
        router.attach_stream_shards(vec![vec![ShardTx::new(tx, waker)]]);
        let target = RingTarget { shard: 0, conn: 42 };
        assert!(router.submit_streaming_ring(req(16), target));
        // play the shard loop: drain the ring until the terminal frame
        let mut frames: Vec<StreamFrame> = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while !frames.last().is_some_and(|f| f.done) {
            match rx.try_pop() {
                Some(f) => frames.push(f),
                None => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "ring stream must terminate"
                    );
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        assert!(frames.len() >= 2, "deltas then the terminal frame");
        assert!(frames.iter().all(|f| f.conn == 42));
        assert!(frames[..frames.len() - 1].iter().all(|f| !f.done));
        let last = frames.last().unwrap();
        assert!(
            last.bytes.ends_with(b"0\r\n\r\n"),
            "terminal frame carries the chunked-body terminator"
        );
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn ring_consumer_hangup_does_not_wedge_replica() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        // tiny ring: the stream overflows it immediately, and then the
        // consumer vanishes (shard loop death) mid-stream
        let (tx, rx) = spsc::ring(2);
        let waker = Arc::new(Waker::new().expect("waker"));
        router.attach_stream_shards(vec![vec![ShardTx::new(tx, waker)]]);
        assert!(router.submit_streaming_ring(req(64), RingTarget { shard: 0, conn: 1 }));
        drop(rx);
        // the replica discards undeliverable frames and keeps serving
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        router.shutdown();
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn streaming_subscriber_hangup_does_not_wedge_replica() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        drop(router.submit_streaming(req(64))); // client vanished immediately
        // the replica keeps serving fresh traffic and load drains to zero
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        router.shutdown();
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn submit_after_shutdown_errors_cleanly() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        router.shutdown();
        assert!(router.complete(req(4)).is_err());
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn aggregated_metrics_sum_replica_counters() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..8).map(|_| router.submit(req(12))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let per = router.replica_metrics();
        assert_eq!(per.len(), 2);
        let agg = router.aggregated_metrics();
        assert_eq!(
            agg.tokens_out,
            per.iter().map(|m| m.tokens_out).sum::<u64>()
        );
        assert_eq!(agg.completed, 8);
        // round-robin with blocking-free submission: both replicas worked
        assert!(per.iter().all(|m| m.completed == 4));
        router.shutdown();
    }

    #[test]
    fn record_hook_sees_every_submission_with_assigned_ids() {
        let seen: Arc<Mutex<Vec<(u64, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let mut router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let sink = seen.clone();
        router.set_record_hook(Box::new(move |r| {
            sink.lock().unwrap().push((r.id, r.prompt.len()));
        }));
        let rx1 = router.submit(req(4));
        let rx2 = router.submit_streaming(req(6));
        rx1.recv().unwrap();
        for _ in rx2 {}
        let seen = seen.lock().unwrap().clone();
        assert_eq!(seen.len(), 2, "blocking AND streaming submissions fire");
        assert_eq!(seen[0], (1, 24), "hook sees the router-assigned id");
        assert_eq!(seen[1], (2, 24));
        router.shutdown();
    }

    #[test]
    fn metrics_json_has_aggregate_and_per_replica_views() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        let fin = router.complete(req(6)).unwrap();
        assert_eq!(fin.output.len(), 6);
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"replica_count\":2"), "{s}");
        assert!(s.contains("\"route_policy\":\"least-loaded\""), "{s}");
        assert!(s.contains("\"replicas\":["), "{s}");
        assert!(s.contains("block_efficiency"), "{s}");
        router.shutdown();
    }
}
