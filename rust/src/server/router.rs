//! Multi-replica engine router: horizontal scale for the serving stack.
//!
//! An [`EngineRouter`] owns N engine replicas — each with its own model
//! instance, KV cache, scheduler, and dedicated thread running the staged
//! `plan → execute → apply` loop — and dispatches requests to them by a
//! pluggable [`RoutePolicy`] (round-robin or least-loaded by in-flight
//! count).  It aggregates [`MetricsSnapshot`]s across replicas for
//! `/v1/metrics` and performs a graceful drain on shutdown: every replica
//! finishes its in-flight batch before its thread exits.
//!
//! Requests can complete two ways:
//! * [`EngineRouter::submit`] / [`EngineRouter::complete`] — one
//!   [`FinishedRequest`] when the whole output exists;
//! * [`EngineRouter::submit_streaming`] — a [`StreamEvent`] channel that
//!   carries every accepted-token delta as the engine's step loop applies
//!   it ([`StreamEvent::Delta`]), then the finished-request summary
//!   ([`StreamEvent::Done`]); the channel closes after the terminal event.
//!   Drain still delivers every delta and the terminal event; abort
//!   terminates open streams with a `FinishReason::Aborted` summary.
//!
//! Replicas are share-nothing for *execution*: no KV or signal state
//! crosses the boundary, so aggregate throughput scales with replica count
//! until the host runs out of cores (see `benches/serving_load.rs`).  Two
//! placement layers do look across the boundary:
//!
//! * **KV-aware routing** ([`RoutePolicy::KvAware`]): each replica thread
//!   publishes a [`ReplicaLoad`] snapshot (KV occupancy + queue pressure)
//!   into a lock-free load cell after every step; `submit` picks the
//!   replica with the most projected KV-block headroom for the candidate
//!   request (prompt + output budget), instead of the fewest in-flight
//!   requests.  Request counts are blind to sequence length; blocks are
//!   the resource that actually saturates.
//! * **Work stealing** ([`EngineRouter::with_options`]): the supervisor
//!   thread watches the load cells; when a replica goes idle while a
//!   sibling still has ≥2 queued (not in-flight) requests, it migrates
//!   untouched queued requests to the idle replica, fixing the drain-tail
//!   imbalance.  Only never-run sequences migrate, so placement can never
//!   change a request's output tokens.
//!
//! # Failure model & recovery
//!
//! Every routed request lives in a router-global **ledger**
//! (`id → {durable request copy, reply channel, owning replica}`) from
//! dispatch until its terminal event is delivered.  Replica threads run
//! under `catch_unwind`; a supervisor thread (always running, even with
//! stealing disabled) detects
//!
//! * **death** — the thread panicked or exited (its `alive` flag drops),
//! * **wedging** — the replica holds work but has neither heartbeat nor
//!   fresh dispatch inside the configured stall window
//!   ([`RouterOptions::stall_ms`]; `0` disables stall detection),
//!
//! marks the replica failed in its load cell (surfaced as
//! [`ReplicaLoad::failed`] and on `/v1/metrics`), and drains its ledger
//! entries: blocking requests and never-progressed streams are resubmitted
//! to survivors with their accrued queue wait carried over
//! (`Request::waited`), while streams that already delivered bytes get a
//! clean `FinishReason::Aborted` terminal — **every client observes
//! exactly one terminal event, never a hang**.  Routing and stealing skip
//! failed replicas; with no survivors, clients get aborted terminals
//! rather than silence.  Fault injection for tests threads through
//! [`RouterOptions::fault`] (see [`crate::util::fault::FaultPlan`]).

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{RateLimit, RoutePolicy, SpecControl};
use crate::engine::engine::{Engine, ReplicaLoad, StepOutcome};
use crate::engine::metrics::{MetricsSnapshot, DEFAULT_QUANTILES};
use crate::engine::request::{FinishReason, FinishedRequest, Request};
use crate::engine::step::StepReport;
use crate::log_warn;
use crate::spec::control::{
    ControlCell, ControlConfig, ControlExport, Controller, ReplicaSample,
};
use crate::util::fault::{ArmedFaults, FaultPlan};
use crate::util::json::Json;
use crate::util::spsc;
use crate::util::sys::Waker;

use super::conn::{stream_abort_frame_in, stream_delta_frame_in, stream_done_frame_in};
use crate::util::bufpool::{BufPool, Frame};
use super::journal::Journal;

/// Hook invoked with every routed request right after its router-global
/// id is assigned and before it is dispatched to a replica — the serving
/// stack's trace-record point (`--record`; see
/// [`crate::eval::trace::TraceRecorder`] and the write-ahead
/// [`Journal`]).  Fires on the submitting thread, so implementations
/// should stay cheap (both recorders do one buffered line write).
pub type RecordHook = Box<dyn Fn(&Request) + Send + Sync>;

/// One event on a streaming request's channel.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Tokens accepted for this request in one engine step.
    Delta {
        /// The accepted tokens, in generation order.
        tokens: Vec<u32>,
        /// Engine-clock time the tokens were applied at.
        t: f64,
    },
    /// Terminal event: the completed request summary.  The channel closes
    /// after this is delivered.
    Done(FinishedRequest),
}

/// A reply sender plus the optional event-loop waker poked after every
/// successful send.  This is the nonblocking notification path of the
/// poll-based front-end: the replica thread delivers on the plain mpsc
/// channel exactly as before, then pokes the waker so the event loop
/// wakes and `try_recv`s — no blocking `recv` anywhere on the loop.  The
/// threaded front-end passes no waker and the wrapper is free.  Waker
/// pokes coalesce inside [`Waker::wake`] (an atomic wake-pending flag),
/// so a burst of deliveries between two loop iterations costs one
/// eventfd/pipe write, not one per delivery.
pub(crate) struct Notify<T> {
    tx: Sender<T>,
    waker: Option<Arc<Waker>>,
}

impl<T> Notify<T> {
    fn new(tx: Sender<T>, waker: Option<Arc<Waker>>) -> Notify<T> {
        Notify { tx, waker }
    }

    fn send(&self, v: T) -> Result<(), std::sync::mpsc::SendError<T>> {
        let r = self.tx.send(v);
        if r.is_ok() {
            if let Some(w) = &self.waker {
                w.wake();
            }
        }
        r
    }
}

/// Per-(replica, shard) SPSC ring capacity in frames.  Deep enough that a
/// full ring means the shard loop has not run for hundreds of deliveries;
/// overflow then spills to the replica-local queue (see [`ShardTx`])
/// rather than blocking the engine or dropping frames.
pub(crate) const STREAM_RING_CAP: usize = 1024;

/// One preformatted NDJSON stream frame bound for an event-loop shard:
/// the bytes are chunk-encoded once, on the replica thread, into a
/// refcounted pooled buffer — the shard loop enqueues the [`Frame`] on
/// the connection's output queue by reference and `writev` flushes it
/// without ever copying the payload.
pub(crate) struct StreamFrame {
    /// Event-loop connection token the frame belongs to (frames whose
    /// connection has closed are discarded by the shard loop).
    pub(crate) conn: u64,
    /// Wire bytes, ready to flush; the backing buffer returns to the
    /// replica's frame pool when the last reference drops.
    pub(crate) bytes: Frame,
    /// Terminal frame: carries the done summary plus the chunked-encoding
    /// terminator; the stream is complete once these bytes flush.
    pub(crate) done: bool,
}

/// Where a ring-delivered stream's frames go: which loop shard consumes
/// them and which connection (by token) they belong to.  Replica-neutral,
/// so work stealing and failover migrate ring streams like any other
/// reply channel — every replica holds a producer to every shard.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RingTarget {
    /// Index of the event-loop shard that owns the connection.
    pub(crate) shard: usize,
    /// The connection's loop-assigned token.
    pub(crate) conn: u64,
}

/// A replica's producer endpoint for one event-loop shard: the SPSC ring,
/// the shard's waker (pokes coalesce in [`Waker::wake`]), and a
/// replica-local overflow queue.
///
/// A full ring normally backpressures the producer — but a replica thread
/// must never *block* on a shard loop, because the loop itself can block
/// on the replica (a `/v1/metrics` dispatch does a synchronous metrics
/// round-trip); parking here could deadlock the pair.  So a frame that
/// cannot enter the ring is parked in `overflow` (unbounded, exactly the
/// delivery guarantee the old per-request mpsc channels gave) and retried
/// on every subsequent send and once per replica-loop iteration.  Frames
/// are never dropped while the consumer lives; a dropped consumer (shard
/// loop exited) discards them, matching the old hung-up-subscriber
/// semantics.
pub(crate) struct ShardTx {
    tx: spsc::Producer<StreamFrame>,
    waker: Arc<Waker>,
    overflow: VecDeque<StreamFrame>,
}

impl ShardTx {
    /// Wrap a ring producer and the owning shard's waker.
    pub(crate) fn new(tx: spsc::Producer<StreamFrame>, waker: Arc<Waker>) -> ShardTx {
        ShardTx {
            tx,
            waker,
            overflow: VecDeque::new(),
        }
    }

    /// Retry delivery of parked frames (oldest first, preserving order).
    /// Returns true when nothing remains to deliver — the overflow is
    /// empty, or the consumer is gone and the backlog was discarded.
    fn pump(&mut self) -> bool {
        if self.tx.is_closed() {
            self.overflow.clear();
            return true;
        }
        let mut pushed = false;
        while let Some(frame) = self.overflow.pop_front() {
            match self.tx.try_push(frame) {
                Ok(()) => pushed = true,
                Err(spsc::PushError::Full(f)) => {
                    self.overflow.push_front(f);
                    break;
                }
                Err(spsc::PushError::Closed(_)) => {
                    self.overflow.clear();
                    return true;
                }
            }
        }
        if pushed {
            self.waker.wake();
        }
        self.overflow.is_empty()
    }

    /// Queue one frame for the shard, preserving per-connection order:
    /// ring first, replica-local overflow when the ring is full.
    fn send(&mut self, frame: StreamFrame) {
        if self.tx.is_closed() {
            self.overflow.clear();
            return;
        }
        self.pump();
        if !self.overflow.is_empty() {
            self.overflow.push_back(frame);
            return;
        }
        match self.tx.try_push(frame) {
            Ok(()) => self.waker.wake(),
            Err(spsc::PushError::Full(f)) => {
                self.overflow.push_back(f);
                // the ring has frames regardless; make sure the shard is
                // awake to drain them
                self.waker.wake();
            }
            Err(spsc::PushError::Closed(_)) => {}
        }
    }

    /// Whether parked frames are waiting for ring space.
    fn has_backlog(&self) -> bool {
        !self.overflow.is_empty()
    }
}

/// Retry every shard's parked frames; true when all are delivered (or
/// discarded because their consumer is gone).
fn pump_shards(shards: &mut [ShardTx]) -> bool {
    let mut all = true;
    for s in shards.iter_mut() {
        if !s.pump() {
            all = false;
        }
    }
    all
}

/// Block (politely) until every parked frame is delivered or its consumer
/// is gone — the replica-exit path, so terminal frames written during
/// drain/abort cannot be lost with the thread.
fn flush_shards_before_exit(shards: &mut [ShardTx]) {
    while !pump_shards(shards) {
        std::thread::sleep(Duration::from_micros(100));
    }
}

/// The reply channel of a request in flight — held in the router-global
/// ledger so stealing and failover migrate it invisibly to the waiting
/// client.
pub(crate) enum ReplyTo {
    /// Blocking submitter waiting for the one [`FinishedRequest`].
    Blocking(Notify<FinishedRequest>),
    /// Streaming subscriber consuming [`StreamEvent`]s.
    Streaming(Notify<StreamEvent>),
    /// Event-loop stream delivered as preformatted frames on the target
    /// shard's ring.  Replica-independent, so it migrates freely.
    Ring(RingTarget),
}

/// One routed request's ledger entry: everything needed to deliver its
/// terminal event — or to replay it on another replica if its current
/// owner dies.  Lives from dispatch until the terminal event is sent.
struct LedgerEntry {
    /// Durable copy of the request (replicas get clones); failover
    /// resubmits from this.
    req: Request,
    /// Where the terminal event (and stream deltas) go.
    reply: ReplyTo,
    /// Index of the replica currently responsible for running the
    /// request.  Only the owner delivers; a stale owner's deliveries are
    /// ignored, which is what makes migration race-free.
    replica: usize,
    /// Whether any stream bytes reached the client.  A progressed stream
    /// cannot be replayed (the prefix is already on the wire), so failover
    /// aborts it instead of resubmitting.
    progressed: bool,
    /// When the request was (last) handed to its owning replica; accrued
    /// wall-clock wait is folded into `req.waited` on migration.
    enqueued: Instant,
}

/// State shared between dispatchers, replica threads, and the supervisor.
struct RouterShared {
    /// The request ledger: every in-flight request, by router-global id.
    ledger: Mutex<HashMap<u64, LedgerEntry>>,
    /// Write-ahead journal, when `--record` is active (completion markers
    /// are written from whichever thread delivers the terminal event).
    journal: Mutex<Option<Arc<Journal>>>,
    /// Replicas declared failed by the supervisor so far.
    failures: AtomicU64,
    /// Requests re-dispatched to a survivor after their replica failed.
    resubmitted: AtomicU64,
    /// Router birth; heartbeat/dispatch stamps are milliseconds since
    /// this.
    epoch: Instant,
    /// Armed fault-injection schedule (tests only; `None` in production).
    faults: Option<ArmedFaults>,
    /// Stall window in milliseconds for wedge detection; `0` disables it
    /// (panic/death detection stays on).
    stall_ms: u64,
}

impl RouterShared {
    fn new(stall_ms: u64, faults: Option<ArmedFaults>) -> RouterShared {
        RouterShared {
            ledger: Mutex::new(HashMap::new()),
            journal: Mutex::new(None),
            failures: AtomicU64::new(0),
            resubmitted: AtomicU64::new(0),
            epoch: Instant::now(),
            faults,
            stall_ms,
        }
    }

    /// Milliseconds elapsed since the router was built.
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Clone the journal handle (cheap; taken once per delivery batch).
    fn journal(&self) -> Option<Arc<Journal>> {
        self.journal.lock().expect("journal lock").clone()
    }
}

/// Messages into a replica's engine thread.  Reply routing is looked up
/// in the ledger, so submissions carry only the request.
pub(crate) enum EngineMsg {
    /// Submit a request (fresh, or a failover resubmission).
    Submit(Request),
    /// Work stealing, thief side: adopt migrated requests (their ledger
    /// entries were re-owned by the supervisor before this was sent).
    SubmitStolen(Vec<Request>),
    /// Install this replica's per-shard ring producers plus its frame
    /// pool (ring frames are encoded into recycled pooled buffers).  Sent
    /// once per replica before the front-end starts accepting, so channel
    /// FIFO guarantees it precedes every ring submission.
    AttachShards(Vec<ShardTx>, BufPool),
    /// Write an aborted terminal frame for each ring target — failover's
    /// path for terminating progressed ring streams whose owning replica
    /// died (any live replica can produce to any shard).
    AbortRings(Vec<RingTarget>),
    /// Work stealing, victim side: migrate up to `max` untouched waiting
    /// requests back to the supervisor.  Replies with an empty batch when
    /// nothing is stealable.
    Steal(usize, Sender<Vec<Request>>),
    /// Snapshot this replica's metrics, pre-reduced to scalars plus the
    /// requested percentiles (never the full retained request window).
    Metrics(Vec<f64>, Sender<MetricsSnapshot>),
    /// Graceful drain: finish everything in flight, then exit the thread.
    Drain,
    /// Abort in-flight work (clients observe `FinishReason::Aborted`) and
    /// exit the thread.
    Abort,
}

/// Projected token demand of a request: its prompt plus the full output
/// budget it may grow to — the KV footprint placement must plan for.
fn projected_tokens(req: &Request) -> usize {
    req.prompt.len() + req.params.max_tokens
}

/// Decrement an in-flight gauge, saturating at zero.  The supervisor
/// zeroes a failed replica's gauge wholesale, which can race a delivery
/// that already removed its ledger entry; underflowing to `usize::MAX`
/// would poison every load-based decision, so lose the decrement instead.
fn dec_load(load: &AtomicUsize) {
    let _ = load.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
}

/// Lock-free per-replica load gauges shared between the replica thread
/// (publisher), the router's submit path (KV-aware pick), and the
/// supervisor (steal trigger + failure detection).  Staleness is bounded
/// by one engine step; the `channel_*` pair covers the gap between a
/// submit and the replica's next intake, so a burst of submissions is
/// visible to placement immediately.
pub(crate) struct LoadCell {
    /// Tokens per KV block (immutable; set at construction).
    block_size: usize,
    /// Sequences currently scheduled in the running batch.
    in_flight: AtomicUsize,
    /// KV blocks currently mapped.
    kv_used_blocks: AtomicUsize,
    /// KV blocks currently free.
    kv_free_blocks: AtomicUsize,
    /// Requests waiting in the engine's admission queue.
    queued_requests: AtomicUsize,
    /// Projected token demand of the engine's waiting queue.
    queued_prompt_tokens: AtomicUsize,
    /// Requests sent to the replica's channel but not yet taken in
    /// (router/supervisor adds, replica subtracts on intake).
    channel_requests: AtomicUsize,
    /// Projected token demand of the channel backlog.
    channel_tokens: AtomicUsize,
    /// Set (once, by the supervisor) when the replica is declared dead or
    /// wedged.  Routing, stealing, and metrics scrapes skip failed
    /// replicas; the replica thread itself exits on observing the flag.
    failed: AtomicBool,
    /// Engine `max_batch` (immutable; controller occupancy denominator).
    max_batch: usize,
    /// Cumulative accepted draft tokens (controller goodput numerator).
    ctl_accepted: AtomicU64,
    /// Cumulative round cost in microseconds (goodput denominator).
    ctl_busy_us: AtomicU64,
    /// Last metrics snapshot the replica published while healthy — the
    /// "black box" served instead of a live scrape once the replica is
    /// failed or gone, so work it delivered before dying stays in fleet
    /// aggregates exactly once (resubmitted requests accrue only on
    /// their new owner).
    retained: Mutex<MetricsSnapshot>,
}

impl LoadCell {
    fn new(engine: &Engine) -> LoadCell {
        let snap = engine.load_snapshot();
        LoadCell {
            block_size: engine.kv_block_size(),
            in_flight: AtomicUsize::new(snap.in_flight),
            kv_used_blocks: AtomicUsize::new(snap.kv_used_blocks),
            kv_free_blocks: AtomicUsize::new(snap.kv_free_blocks),
            queued_requests: AtomicUsize::new(snap.queued_requests),
            queued_prompt_tokens: AtomicUsize::new(snap.queued_prompt_tokens),
            channel_requests: AtomicUsize::new(0),
            channel_tokens: AtomicUsize::new(0),
            failed: AtomicBool::new(false),
            max_batch: engine.cfg.max_batch,
            ctl_accepted: AtomicU64::new(0),
            ctl_busy_us: AtomicU64::new(0),
            retained: Mutex::new(MetricsSnapshot::default()),
        }
    }

    /// Replica thread: publish fresh engine-truth gauges.  Never touches
    /// the `failed` flag — that belongs to the supervisor.
    fn publish(&self, snap: &ReplicaLoad) {
        self.in_flight.store(snap.in_flight, Ordering::SeqCst);
        self.kv_used_blocks.store(snap.kv_used_blocks, Ordering::SeqCst);
        self.kv_free_blocks.store(snap.kv_free_blocks, Ordering::SeqCst);
        self.queued_requests.store(snap.queued_requests, Ordering::SeqCst);
        self.queued_prompt_tokens
            .store(snap.queued_prompt_tokens, Ordering::SeqCst);
    }

    /// Router/supervisor: a request was sent to the replica's channel.
    fn on_enqueue(&self, req: &Request) {
        self.channel_requests.fetch_add(1, Ordering::SeqCst);
        self.channel_tokens
            .fetch_add(projected_tokens(req), Ordering::SeqCst);
    }

    /// Undo [`LoadCell::on_enqueue`] (failed send, or replica intake).
    fn on_dequeue(&self, req: &Request) {
        self.channel_requests.fetch_sub(1, Ordering::SeqCst);
        self.channel_tokens
            .fetch_sub(projected_tokens(req), Ordering::SeqCst);
    }

    /// Queue depth the supervisor sees: engine waiting + channel backlog.
    fn queued_total(&self) -> usize {
        self.queued_requests.load(Ordering::SeqCst)
            + self.channel_requests.load(Ordering::SeqCst)
    }

    /// Replica thread: accumulate controller inputs after a ran round.
    fn note_step(&self, accepted: usize, cost: f64) {
        self.ctl_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
        self.ctl_busy_us
            .fetch_add((cost * 1e6) as u64, Ordering::Relaxed);
    }

    /// Controller: cumulative (accepted tokens, busy µs) counters.
    fn control_counters(&self) -> (u64, u64) {
        (
            self.ctl_accepted.load(Ordering::Relaxed),
            self.ctl_busy_us.load(Ordering::Relaxed),
        )
    }

    /// Controller: running-batch occupancy (the `in_flight` gauge drains
    /// to zero when the replica idles, unlike a last-round batch size).
    fn occupancy(&self) -> f64 {
        if self.max_batch == 0 {
            return 0.0;
        }
        self.in_flight.load(Ordering::SeqCst) as f64 / self.max_batch as f64
    }

    /// Replica thread: refresh the metrics black box.  Callers gate on
    /// `!is_failed()` so a condemned replica cannot re-accrue work that
    /// failover already resubmitted elsewhere.
    fn record_metrics(&self, snap: MetricsSnapshot) {
        *self.retained.lock().unwrap() = snap;
    }

    /// The last snapshot published while the replica was healthy.
    fn retained_metrics(&self) -> MetricsSnapshot {
        self.retained.lock().unwrap().clone()
    }

    /// Supervisor: declare this replica failed (one-way).
    fn mark_failed(&self) {
        self.failed.store(true, Ordering::SeqCst);
    }

    /// Whether the supervisor has declared this replica failed.
    fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    /// Projected free blocks after this replica absorbs its queued work,
    /// channel backlog, and the candidate request.  Negative = projected
    /// KV over-subscription (preemption thrash ahead).
    fn kv_headroom(&self, candidate_tokens: usize) -> isize {
        let free = self.kv_free_blocks.load(Ordering::SeqCst) as isize;
        let backlog = self.queued_prompt_tokens.load(Ordering::SeqCst)
            + self.channel_tokens.load(Ordering::SeqCst)
            + candidate_tokens;
        free - backlog.div_ceil(self.block_size) as isize
    }

    /// Snapshot the published gauges (channel backlog folded into the
    /// queue fields so callers see the router-wide truth).
    fn snapshot(&self) -> ReplicaLoad {
        ReplicaLoad {
            in_flight: self.in_flight.load(Ordering::SeqCst),
            kv_used_blocks: self.kv_used_blocks.load(Ordering::SeqCst),
            kv_free_blocks: self.kv_free_blocks.load(Ordering::SeqCst),
            queued_requests: self.queued_total(),
            queued_prompt_tokens: self.queued_prompt_tokens.load(Ordering::SeqCst)
                + self.channel_tokens.load(Ordering::SeqCst),
            failed: self.is_failed(),
        }
    }
}

/// One engine replica: channel + thread + in-flight counter + load gauges
/// + liveness instrumentation for the supervisor.
struct Replica {
    tx: Sender<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
    /// Cleared by the thread wrapper when the replica loop returns or
    /// panics — the supervisor's death signal.
    alive: Arc<AtomicBool>,
    /// Last top-of-loop stamp (ms since router epoch) from the replica
    /// thread — the supervisor's wedge signal.
    heartbeat: Arc<AtomicU64>,
    /// Last time (ms since router epoch) work was handed to this replica;
    /// guards wedge detection against flagging a replica that was idle
    /// (heartbeat legitimately stale) when work just arrived.
    last_dispatch: Arc<AtomicU64>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

/// The aborted-terminal summary synthesized for a request that cannot be
/// completed (its replica died with no survivors, or its stream already
/// progressed and cannot be replayed).
fn aborted_fin(req: &Request) -> FinishedRequest {
    FinishedRequest {
        id: req.id,
        output: Vec::new(),
        reason: FinishReason::Aborted,
        arrival: req.arrival,
        finished_at: req.arrival,
        first_token_at: req.arrival,
        rounds: 0,
        drafted: 0,
        accepted: 0,
        preemptions: 0,
        tenant: req.tenant.clone(),
        class: req.class,
        deadline_ms: req.deadline_ms,
    }
}

/// Deliver one aborted terminal: journal the completion marker and send
/// the summary on the reply channel.  Ring streams cannot be aborted from
/// an arbitrary thread (frames must come from a replica-owned producer),
/// so their targets are collected for the caller to route via
/// [`EngineMsg::AbortRings`] — or to leave to ring-close synthesis in the
/// shard when no replica survives.
fn deliver_abort(
    entry: LedgerEntry,
    journal: &Option<Arc<Journal>>,
    ring_aborts: &mut Vec<RingTarget>,
) {
    if let Some(j) = journal {
        j.record_complete(entry.req.id, "aborted");
    }
    let fin = aborted_fin(&entry.req);
    match entry.reply {
        ReplyTo::Blocking(tx) => {
            let _ = tx.send(fin);
        }
        ReplyTo::Streaming(tx) => {
            let _ = tx.send(StreamEvent::Done(fin));
        }
        ReplyTo::Ring(target) => ring_aborts.push(target),
    }
}

/// Deliver finished requests to their ledger reply channels — blocking
/// submitters get the [`FinishedRequest`], streaming subscribers get the
/// terminal [`StreamEvent::Done`] (which also closes their channel), and
/// ring streams get a terminal [`StreamFrame`] carrying the done summary
/// plus the chunked-encoding terminator.  Only entries this replica still
/// *owns* are delivered: after a failover migrated a request elsewhere,
/// the stale owner's completion is discarded (the new owner will deliver
/// its own), so clients can never see two terminals.
fn deliver(
    engine: &mut Engine,
    my_idx: usize,
    shared: &RouterShared,
    shards: &mut [ShardTx],
    pool: &BufPool,
    load: &AtomicUsize,
) {
    let fins = engine.take_finished();
    if fins.is_empty() {
        return;
    }
    let journal = shared.journal();
    for fin in fins {
        let entry = {
            let mut ledger = shared.ledger.lock().expect("ledger lock");
            match ledger.get(&fin.id) {
                Some(e) if e.replica == my_idx => ledger.remove(&fin.id),
                _ => None, // migrated off this replica; not ours to deliver
            }
        };
        let Some(entry) = entry else { continue };
        dec_load(load);
        if let Some(j) = &journal {
            j.record_complete(fin.id, fin.reason.name());
        }
        match entry.reply {
            ReplyTo::Blocking(tx) => {
                let _ = tx.send(fin);
            }
            ReplyTo::Streaming(tx) => {
                let _ = tx.send(StreamEvent::Done(fin));
            }
            ReplyTo::Ring(target) => {
                if let Some(shard) = shards.get_mut(target.shard) {
                    shard.send(StreamFrame {
                        conn: target.conn,
                        bytes: stream_done_frame_in(pool, &fin),
                        done: true,
                    });
                }
            }
        }
    }
}

/// Forward one step's accepted-token deltas to their streaming
/// subscribers, looked up in the ledger.  Takes the report by value so
/// the token vectors move into the channel instead of being cloned on the
/// per-step hot path.  Marks entries `progressed` on the first delivered
/// bytes — the point after which failover must abort rather than replay.
/// A hung-up subscriber stops receiving but its request still runs to
/// completion and is accounted normally.  Ring frames are chunk-encoded
/// here, on the replica thread, so the shard loop only ever appends
/// ready-made bytes.
fn forward_deltas(
    report: StepReport,
    my_idx: usize,
    shared: &RouterShared,
    shards: &mut [ShardTx],
    pool: &BufPool,
) {
    if report.deltas.is_empty() {
        return;
    }
    let mut ledger = shared.ledger.lock().expect("ledger lock");
    for d in report.deltas {
        let Some(entry) = ledger.get_mut(&d.id) else {
            continue;
        };
        if entry.replica != my_idx {
            continue; // migrated away; the new owner forwards
        }
        let progressed = match &entry.reply {
            ReplyTo::Streaming(tx) => tx
                .send(StreamEvent::Delta {
                    tokens: d.tokens,
                    t: d.t,
                })
                .is_ok(),
            ReplyTo::Ring(target) => {
                let target = *target;
                match shards.get_mut(target.shard) {
                    Some(shard) => {
                        shard.send(StreamFrame {
                            conn: target.conn,
                            bytes: stream_delta_frame_in(pool, &d.tokens, d.t),
                            done: false,
                        });
                        true
                    }
                    None => false,
                }
            }
            ReplyTo::Blocking(_) => false, // nothing reaches the client early
        };
        if progressed {
            entry.progressed = true;
        }
    }
}

/// A replica's engine thread: interleave request intake with engine steps
/// so new arrivals join the continuous batch.  Publishes fresh load gauges
/// into `cell` after every intake round and every step, stamps `heartbeat`
/// every iteration (the supervisor's wedge signal), honors injected
/// kill/stall faults, and exits promptly once the supervisor declares it
/// failed (its work has been migrated; delivering anything further would
/// be a stale double).
fn replica_loop(
    mut engine: Engine,
    my_idx: usize,
    rx: Receiver<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
    heartbeat: Arc<AtomicU64>,
    shared: Arc<RouterShared>,
) {
    let mut shards: Vec<ShardTx> = Vec::new();
    // replaced by AttachShards; frames are only built once shards exist,
    // so the uncached placeholder never sees traffic
    let mut frame_pool = BufPool::new(0);
    let mut draining = false;
    let mut consecutive_errors = 0u32;
    loop {
        heartbeat.store(shared.now_ms(), Ordering::SeqCst);
        if cell.is_failed() {
            // the supervisor failed us over; our ledger entries belong to
            // other replicas now
            return;
        }
        if let Some(faults) = &shared.faults {
            if let Some(stall) = faults.stall_due(my_idx) {
                log_warn!("fault injection: stalling replica {my_idx} for {stall:?}");
                // no heartbeat is published for the stall's duration (that
                // is the wedge being simulated), but sleep in slices so a
                // replica the supervisor has already failed over exits
                // instead of pinning shutdown for the rest of the stall
                let until = Instant::now() + stall;
                while Instant::now() < until && !cell.is_failed() {
                    std::thread::sleep(Duration::from_millis(10));
                }
                continue;
            }
            if faults.kill_due(my_idx) {
                panic!("fault injection: kill replica {my_idx}");
            }
        }
        // drain the message queue (blocking when idle, else non-blocking)
        let mut took_msg = false;
        loop {
            let idle = engine.pending() == 0
                && load.load(Ordering::SeqCst) == 0
                && !shards.iter().any(|s| s.has_backlog())
                && !draining;
            let msg = if idle {
                if shared.faults.is_some() {
                    // armed faults must fire even on an idle replica: poll
                    // instead of parking forever in recv()
                    match rx.recv_timeout(Duration::from_millis(5)) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                } else {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return, // router dropped: nothing in flight
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true; // router gone: finish what we hold
                        break;
                    }
                }
            };
            match msg {
                EngineMsg::Submit(req) => {
                    cell.on_dequeue(&req);
                    engine.submit(req);
                }
                EngineMsg::SubmitStolen(batch) => {
                    for req in batch {
                        cell.on_dequeue(&req);
                        engine.submit(req);
                    }
                }
                EngineMsg::AttachShards(s, p) => {
                    shards = s;
                    frame_pool = p;
                }
                EngineMsg::AbortRings(targets) => {
                    for t in targets {
                        if let Some(shard) = shards.get_mut(t.shard) {
                            shard.send(StreamFrame {
                                conn: t.conn,
                                bytes: stream_abort_frame_in(&frame_pool),
                                done: true,
                            });
                        }
                    }
                }
                EngineMsg::Steal(max, reply) => {
                    // ledger ownership stays with this replica until the
                    // supervisor re-owns the entries; only the accrued
                    // wait migrates into the durable copies here
                    let batch = engine.steal_waiting(max);
                    if !batch.is_empty() {
                        let mut ledger = shared.ledger.lock().expect("ledger lock");
                        for req in &batch {
                            if let Some(e) = ledger.get_mut(&req.id) {
                                e.req.waited = req.waited;
                            }
                        }
                    }
                    if let Err(std::sync::mpsc::SendError(batch)) = reply.send(batch) {
                        // supervisor vanished mid-steal: nothing may be
                        // lost — keep the work local (ownership never left)
                        for req in batch {
                            engine.submit(req);
                        }
                    }
                }
                EngineMsg::Metrics(quantiles, reply) => {
                    let _ = reply.send(engine.metrics.snapshot(&quantiles));
                }
                EngineMsg::Drain => draining = true,
                EngineMsg::Abort => {
                    engine.abort_all();
                    deliver(&mut engine, my_idx, &shared, &mut shards, &frame_pool, &load);
                    cell.publish(&engine.load_snapshot());
                    flush_shards_before_exit(&mut shards);
                    return;
                }
            }
            took_msg = true;
        }
        if took_msg {
            // intake changed the queue; refresh the gauges before stepping
            cell.publish(&engine.load_snapshot());
        }
        if engine.pending() > 0 {
            // the report's post-step snapshot doubles as the publish, so
            // the normal path pays the O(#waiting) scan only once (in
            // apply); abnormal paths below re-snapshot explicitly
            let mut published = false;
            let progressed = match engine.step_detailed() {
                Ok(outcome) => {
                    consecutive_errors = 0;
                    match outcome {
                        StepOutcome::Idle => false,
                        StepOutcome::Retry => true,
                        StepOutcome::Ran(report) => {
                            cell.publish(&report.load);
                            cell.note_step(report.accepted, report.cost);
                            // refresh the metrics black box — every step
                            // under fault injection (failover accounting
                            // must be step-exact), else amortized (the
                            // snapshot sorts the retention window)
                            if (shared.faults.is_some()
                                || engine.metrics.steps % 64 == 0)
                                && !cell.is_failed()
                            {
                                cell.record_metrics(
                                    engine.metrics.snapshot(DEFAULT_QUANTILES),
                                );
                            }
                            published = true;
                            forward_deltas(
                                report, my_idx, &shared, &mut shards, &frame_pool,
                            );
                            true
                        }
                    }
                }
                Err(e) => {
                    consecutive_errors += 1;
                    log_warn!(
                        "engine step error ({consecutive_errors} consecutive): {e:#}"
                    );
                    // a transient failure is worth retrying; a persistently
                    // failing model must not wedge the replica forever
                    consecutive_errors < 3
                }
            };
            deliver(&mut engine, my_idx, &shared, &mut shards, &frame_pool, &load);
            if !progressed && engine.pending() > 0 {
                // Stuck, not just slow.  Two causes, two remedies — either
                // way the replica stays up instead of busy-spinning and
                // starving everything routed here:
                if consecutive_errors >= 3 {
                    // persistently failing model: the whole batch is
                    // unservable; clients observe FinishReason::Aborted
                    log_warn!(
                        "model failing persistently; aborting {} request(s)",
                        engine.pending()
                    );
                    engine.abort_all();
                    consecutive_errors = 0;
                } else {
                    // head-of-line prompt that can never fit in KV (FCFS
                    // forbids skipping it): abort just the head so the
                    // servable requests queued behind it proceed
                    if let Some(id) = engine.abort_head() {
                        log_warn!(
                            "aborting unschedulable request {id} \
                             (prompt cannot fit in KV)"
                        );
                    }
                }
                deliver(&mut engine, my_idx, &shared, &mut shards, &frame_pool, &load);
                published = false; // aborts changed queue/KV state
            }
            if !published {
                cell.publish(&engine.load_snapshot());
            }
        } else if draining {
            // terminal frames may still be parked in shard overflow
            // queues; they must land (or their consumer must be gone)
            // before this thread — their only producer — exits
            flush_shards_before_exit(&mut shards);
            return;
        } else if shards.iter().any(|s| s.has_backlog()) {
            // engine idle but stream frames are parked waiting for ring
            // space: retry their delivery without busy-spinning
            if !pump_shards(&mut shards) {
                std::thread::sleep(Duration::from_micros(100));
            }
        } else if load.load(Ordering::SeqCst) > 0 {
            // a dispatcher bumped our gauge but its Submit has not landed
            // yet (it sends after the increment); yield briefly instead of
            // hot-spinning through the gap
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

/// How often the supervisor re-examines the load cells while the fleet
/// has work in flight.  Cheap (a handful of atomic loads per replica), so
/// it can afford to be much finer than a round.
const STEAL_POLL: Duration = Duration::from_micros(200);

/// Supervisor poll interval while the fleet is completely idle — no point
/// burning 5k wake-ups/second on a server at zero traffic.  Worst-case
/// added steal/detection latency after an idle period is one of these.
const STEAL_POLL_IDLE: Duration = Duration::from_millis(2);

/// Minimum queued (not in-flight) requests on a replica before the
/// supervisor migrates work off it: a queue of one is the FCFS head and
/// is about to run locally anyway.
const STEAL_MIN_QUEUE: usize = 2;

/// How long the supervisor waits for a steal victim's reply before
/// abandoning the round.  A victim that cannot answer within this is
/// stalled; blocking the supervisor on it would also stall failure
/// detection — the very thing that will rescue the victim's work.
const STEAL_REPLY_TIMEOUT: Duration = Duration::from_millis(200);

/// How long a metrics scrape waits per replica before giving up on it
/// (a wedged replica the supervisor has not condemned yet must not hang
/// `/v1/metrics` forever).
const METRICS_TIMEOUT: Duration = Duration::from_secs(5);

/// The supervisor thread's per-replica handle (its own channel clone +
/// shared gauges; the router's `Replica` structs stay single-owner).
struct SupervisorView {
    tx: Sender<EngineMsg>,
    load: Arc<AtomicUsize>,
    cell: Arc<LoadCell>,
    alive: Arc<AtomicBool>,
    heartbeat: Arc<AtomicU64>,
    last_dispatch: Arc<AtomicU64>,
}

/// Route a batch of aborted-ring terminals through any live replica (all
/// replicas hold producers to every shard).  With no survivors the frames
/// cannot be produced here — the dead producers' closed rings make the
/// shard synthesize the aborted terminal itself.
fn send_ring_aborts(views: &[SupervisorView], targets: Vec<RingTarget>) {
    let mut targets = targets;
    if targets.is_empty() {
        return;
    }
    for v in views {
        if v.cell.is_failed() || !v.alive.load(Ordering::SeqCst) {
            continue;
        }
        match v.tx.send(EngineMsg::AbortRings(targets)) {
            Ok(()) => return,
            Err(std::sync::mpsc::SendError(msg)) => {
                let EngineMsg::AbortRings(t) = msg else {
                    unreachable!("send returns the message it was given")
                };
                targets = t;
            }
        }
    }
}

/// Place a stolen batch on the first candidate replica that accepts it,
/// re-owning the ledger entries and moving load/cell accounting per
/// attempt.  When no candidate accepts (every replica is dead), the
/// batch's clients receive clean aborted terminals and the entries leave
/// the ledger — stolen work is never silently dropped.  Returns the index
/// that accepted, or `None`.
fn place_stolen(
    batch: Vec<Request>,
    candidates: &[usize],
    views: &[SupervisorView],
    shared: &RouterShared,
) -> Option<usize> {
    let mut batch = batch;
    let n = batch.len();
    for &j in candidates {
        let v = &views[j];
        if v.cell.is_failed() || !v.alive.load(Ordering::SeqCst) {
            continue;
        }
        {
            // claim ownership BEFORE the send: from here the receiver (and
            // only the receiver) delivers these requests
            let mut ledger = shared.ledger.lock().expect("ledger lock");
            for req in &batch {
                if let Some(e) = ledger.get_mut(&req.id) {
                    e.replica = j;
                    e.enqueued = Instant::now();
                }
            }
        }
        v.load.fetch_add(n, Ordering::SeqCst);
        for req in &batch {
            v.cell.on_enqueue(req);
        }
        v.last_dispatch.store(shared.now_ms(), Ordering::SeqCst);
        match v.tx.send(EngineMsg::SubmitStolen(batch)) {
            Ok(()) => return Some(j),
            Err(std::sync::mpsc::SendError(msg)) => {
                // candidate died under us: undo its accounting and try the
                // next one with the recovered batch
                for _ in 0..n {
                    dec_load(&v.load);
                }
                let EngineMsg::SubmitStolen(b) = msg else {
                    unreachable!("send returns the message it was given")
                };
                for req in &b {
                    v.cell.on_dequeue(req);
                }
                batch = b;
            }
        }
    }
    // nobody can run the batch: terminate its clients cleanly
    let journal = shared.journal();
    let mut ring_aborts = Vec::new();
    let entries: Vec<LedgerEntry> = {
        let mut ledger = shared.ledger.lock().expect("ledger lock");
        batch.iter().filter_map(|req| ledger.remove(&req.id)).collect()
    };
    for entry in entries {
        deliver_abort(entry, &journal, &mut ring_aborts);
    }
    send_ring_aborts(views, ring_aborts);
    None
}

/// Declare replica `i` failed and rescue its ledger entries: blocking
/// requests and never-progressed streams are resubmitted round-robin to
/// survivors (accrued wait carried in `Request::waited`); progressed
/// streams get a clean aborted terminal (their byte prefix is already on
/// the wire and cannot be replayed).  With no survivors everything gets
/// the aborted terminal.  Clients never hang either way.
fn fail_replica(i: usize, views: &[SupervisorView], shared: &RouterShared) {
    views[i].cell.mark_failed();
    shared.failures.fetch_add(1, Ordering::SeqCst);
    let drained: Vec<LedgerEntry> = {
        let mut ledger = shared.ledger.lock().expect("ledger lock");
        let ids: Vec<u64> = ledger
            .iter()
            .filter(|(_, e)| e.replica == i)
            .map(|(&id, _)| id)
            .collect();
        ids.iter()
            .map(|id| ledger.remove(id).expect("drained id present"))
            .collect()
    };
    views[i].load.store(0, Ordering::SeqCst);
    log_warn!(
        "replica {i} failed; rescuing {} in-flight request(s)",
        drained.len()
    );
    if drained.is_empty() {
        return;
    }
    let survivors: Vec<usize> = (0..views.len())
        .filter(|&j| {
            j != i && views[j].alive.load(Ordering::SeqCst) && !views[j].cell.is_failed()
        })
        .collect();
    let journal = shared.journal();
    let mut ring_aborts: Vec<RingTarget> = Vec::new();
    let mut next = 0usize;
    let mut rescued = 0u64;
    for mut entry in drained {
        let replayable = matches!(&entry.reply, ReplyTo::Blocking(_)) || !entry.progressed;
        if !replayable || survivors.is_empty() {
            deliver_abort(entry, &journal, &mut ring_aborts);
            continue;
        }
        // carry the accrued wait so latency accounting survives the
        // migration (a wall-clock approximation of the engine clock — the
        // two advance together under real serving)
        entry.req.waited += entry.enqueued.elapsed().as_secs_f64();
        let id = entry.req.id;
        let mut pending = Some(entry);
        for off in 0..survivors.len() {
            let j = survivors[(next + off) % survivors.len()];
            let v = &views[j];
            if v.cell.is_failed() || !v.alive.load(Ordering::SeqCst) {
                continue;
            }
            let mut e = pending.take().expect("entry in hand");
            e.replica = j;
            e.enqueued = Instant::now();
            let req = e.req.clone();
            // reinsert BEFORE the send so the new owner finds its entry
            shared.ledger.lock().expect("ledger lock").insert(id, e);
            v.load.fetch_add(1, Ordering::SeqCst);
            v.cell.on_enqueue(&req);
            v.last_dispatch.store(shared.now_ms(), Ordering::SeqCst);
            if v.tx.send(EngineMsg::Submit(req)).is_ok() {
                rescued += 1;
                next = (next + off + 1) % survivors.len();
                break;
            }
            // this survivor died too: reclaim the entry and keep trying
            dec_load(&v.load);
            let e = shared
                .ledger
                .lock()
                .expect("ledger lock")
                .remove(&id)
                .expect("reclaim unsent entry");
            v.cell.on_dequeue(&e.req);
            pending = Some(e);
        }
        if let Some(e) = pending {
            deliver_abort(e, &journal, &mut ring_aborts);
        }
    }
    shared.resubmitted.fetch_add(rescued, Ordering::SeqCst);
    send_ring_aborts(views, ring_aborts);
}

/// The supervisor thread: failure detection plus (optionally) the
/// work-stealing balancer, sharing one polling loop over the load cells.
///
/// * **Detection** — a replica whose thread exited (`alive` dropped), or
///   one holding work with neither heartbeat nor fresh dispatch inside
///   the stall window, is failed over via [`fail_replica`].
/// * **Stealing** — when a replica sits idle while a sibling has a queue,
///   untouched queued requests migrate from the deepest queue to the idle
///   replicas (never through a failed replica, in either direction).
///
/// Runs until the router stops it (always before drain/abort, so healthy
/// replica threads are guaranteed alive and responsive here).
fn supervisor_loop(
    views: Vec<SupervisorView>,
    shared: Arc<RouterShared>,
    steal: bool,
    stop: Arc<AtomicBool>,
    steals: Arc<AtomicU64>,
) {
    while !stop.load(Ordering::SeqCst) {
        // fine-grained polling only while someone has work; idle fleets
        // back off so the thread costs ~nothing at zero traffic
        let busy = views.iter().any(|v| v.load.load(Ordering::SeqCst) > 0);
        std::thread::sleep(if busy { STEAL_POLL } else { STEAL_POLL_IDLE });
        // --- failure detection ---
        let now = shared.now_ms();
        for (i, v) in views.iter().enumerate() {
            if v.cell.is_failed() {
                continue;
            }
            let dead = !v.alive.load(Ordering::SeqCst);
            let wedged = shared.stall_ms > 0
                && v.load.load(Ordering::SeqCst) > 0
                && now.saturating_sub(v.heartbeat.load(Ordering::SeqCst)) > shared.stall_ms
                && now.saturating_sub(v.last_dispatch.load(Ordering::SeqCst))
                    > shared.stall_ms;
            if dead || wedged {
                log_warn!(
                    "replica {i} {}",
                    if dead {
                        "thread died"
                    } else {
                        "stopped heartbeating inside the stall window"
                    }
                );
                fail_replica(i, &views, &shared);
            }
        }
        if !steal {
            continue;
        }
        // --- work stealing (healthy replicas only) ---
        let eligible: Vec<usize> = (0..views.len())
            .filter(|&i| {
                views[i].alive.load(Ordering::SeqCst) && !views[i].cell.is_failed()
            })
            .collect();
        let idle: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| views[i].load.load(Ordering::SeqCst) == 0)
            .collect();
        if idle.is_empty() {
            continue;
        }
        // victim: the deepest queue (engine waiting + channel backlog)
        let Some((victim, depth)) = eligible
            .iter()
            .copied()
            .map(|i| (i, views[i].cell.queued_total()))
            .max_by_key(|&(_, q)| q)
        else {
            continue;
        };
        if depth < STEAL_MIN_QUEUE {
            continue;
        }
        // leave the victim its fair share of its own queue
        let take = depth.div_ceil(idle.len() + 1).max(1);
        for &thief in &idle {
            if thief == victim {
                continue;
            }
            let (btx, brx) = channel();
            if views[victim].tx.send(EngineMsg::Steal(take, btx)).is_err() {
                break; // victim gone; detection handles it next cycle
            }
            // a bounded wait: a stalled victim must not also stall the
            // failure detection that will rescue its work
            let Ok(batch) = brx.recv_timeout(STEAL_REPLY_TIMEOUT) else {
                break;
            };
            if batch.is_empty() {
                break; // nothing stealable (started seqs / head only)
            }
            let n = batch.len();
            // in-flight accounting migrates with the requests, so
            // placement keeps seeing the truth
            for _ in 0..n {
                dec_load(&views[victim].load);
            }
            // candidates: the thief, then the (live) victim, then anyone
            // else — the batch lands somewhere or its clients get clean
            // aborted terminals; it is never dropped
            let mut candidates = vec![thief, victim];
            candidates.extend(
                eligible.iter().copied().filter(|&c| c != thief && c != victim),
            );
            match place_stolen(batch, &candidates, &views, &shared) {
                Some(placed) if placed == thief => {
                    steals.fetch_add(n as u64, Ordering::SeqCst);
                }
                // landed on a fallback (the intended thief died): no steal
                // counted; detection will condemn the thief next cycle
                Some(_) | None => break,
            }
        }
    }
}

/// Reliability knobs for [`EngineRouter::with_router_options`]: the wedge
/// stall window and an optional fault-injection plan (tests only).
#[derive(Clone, Debug)]
pub struct RouterOptions {
    /// Wedge-detection window in milliseconds: a replica holding work
    /// with neither heartbeat nor fresh dispatch for longer than this is
    /// failed over.  `0` disables stall detection (thread-death detection
    /// stays on).
    pub stall_ms: u64,
    /// Deterministic fault-injection schedule threaded into the replica
    /// loops and journal (see [`FaultPlan`]).  `None` in production.
    pub fault: Option<FaultPlan>,
    /// Closed-loop speculation control (`--spec-control`): with
    /// [`SpecControl::Goodput`] a control thread samples per-replica
    /// goodput and tunes the fleet-wide SL cap, per-replica speculation
    /// aggressiveness, and batch admission (see
    /// [`crate::spec::control`]).  Off by default — the engines then run
    /// with no controller attached and plan bit-identically to a router
    /// built without this field.
    pub control: SpecControl,
    /// Per-tenant token-bucket admission control (`--rate-limit`): when
    /// set, both front-ends shed over-rate tenants with `429` before
    /// their requests reach the engines.  `None` admits everything.
    pub rate_limit: Option<RateLimit>,
}

impl Default for RouterOptions {
    fn default() -> Self {
        RouterOptions {
            stall_ms: 10_000,
            fault: None,
            control: SpecControl::Off,
            rate_limit: None,
        }
    }
}

/// Runtime state of the goodput control loop: the `/v1/metrics` export
/// gauges plus the "dsde-spec-ctl" thread's stop/join plumbing.
struct ControlState {
    export: Arc<ControlExport>,
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

/// Per-replica handles the control loop samples from (load cell +
/// liveness) and actuates through (the engine's [`ControlCell`]).
struct ControlTap {
    cell: Arc<LoadCell>,
    alive: Arc<AtomicBool>,
    actuator: Arc<ControlCell>,
}

/// Body of the "dsde-spec-ctl" thread: every `cfg.interval_ms` it derives
/// one [`ReplicaSample`] per replica from the lock-free gauges (goodput =
/// Δaccepted / Δbusy over the interval), ticks the pure [`Controller`],
/// and writes the decision into every engine's actuator cell plus the
/// metrics export.  Wall time only paces sampling — the decision itself
/// is a pure function of the sample stream (see [`crate::spec::control`]),
/// which is what the deterministic eval runner exploits by ticking the
/// same controller from a virtual clock instead.
fn control_loop(
    taps: Vec<ControlTap>,
    cfg: ControlConfig,
    stop: Arc<AtomicBool>,
    export: Arc<ControlExport>,
) {
    let mut ctrl = Controller::new(cfg);
    let mut prev: Vec<(u64, u64)> =
        taps.iter().map(|t| t.cell.control_counters()).collect();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(cfg.interval_ms));
        let samples: Vec<ReplicaSample> = taps
            .iter()
            .zip(prev.iter_mut())
            .map(|(tap, last)| {
                let (acc, busy) = tap.cell.control_counters();
                let d_acc = acc.saturating_sub(last.0);
                let d_busy = busy.saturating_sub(last.1);
                *last = (acc, busy);
                // a dead or condemned replica keeps its last-published
                // gauges forever; the controller must hold rather than
                // chase them (chaos invariant)
                let stale =
                    !tap.alive.load(Ordering::SeqCst) || tap.cell.is_failed();
                let goodput = if d_busy == 0 {
                    0.0
                } else {
                    d_acc as f64 / (d_busy as f64 / 1e6)
                };
                ReplicaSample {
                    goodput,
                    occupancy: tap.cell.occupancy(),
                    queue: tap.cell.queued_total(),
                    stale,
                }
            })
            .collect();
        let decision = ctrl.tick(&samples);
        for (i, tap) in taps.iter().enumerate() {
            tap.actuator.store(
                decision.sl_cap,
                decision.admit_frac,
                decision.aggressiveness[i],
            );
        }
        export.publish(decision.sl_cap, ctrl.adjustments(), ctrl.ref_goodput());
    }
}

/// Routes requests across engine replicas; aggregates their metrics.
pub struct EngineRouter {
    replicas: Vec<Replica>,
    policy: RoutePolicy,
    steal: bool,
    rr_next: AtomicUsize,
    next_id: AtomicU64,
    steals: Arc<AtomicU64>,
    supervisor_stop: Arc<AtomicBool>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
    record: Option<RecordHook>,
    shared: Arc<RouterShared>,
    control: Option<ControlState>,
    limiter: Option<crate::server::limiter::TenantLimiter>,
}

impl EngineRouter {
    /// Spawn one serving thread per engine, work stealing disabled.
    /// Panics on an empty replica set (a router with nothing behind it
    /// cannot serve).
    pub fn new(engines: Vec<Engine>, policy: RoutePolicy) -> EngineRouter {
        EngineRouter::with_options(engines, policy, false)
    }

    /// Spawn one serving thread per engine; with `steal` the supervisor
    /// also migrates untouched queued requests from a backlogged replica
    /// to an idle one (the drain-tail fix).  Stealing never changes a
    /// request's output tokens — only never-run sequences migrate.
    /// Panics on an empty replica set.
    pub fn with_options(
        engines: Vec<Engine>,
        policy: RoutePolicy,
        steal: bool,
    ) -> EngineRouter {
        EngineRouter::with_router_options(engines, policy, steal, RouterOptions::default())
    }

    /// Full-control constructor: [`EngineRouter::with_options`] plus the
    /// reliability knobs in [`RouterOptions`].  The supervisor thread
    /// always runs (failure detection is unconditional); `steal` only
    /// gates the work-stealing half of its loop.
    pub fn with_router_options(
        engines: Vec<Engine>,
        policy: RoutePolicy,
        steal: bool,
        opts: RouterOptions,
    ) -> EngineRouter {
        assert!(!engines.is_empty(), "EngineRouter needs >= 1 engine");
        // a single replica has nobody to steal from: record the EFFECTIVE
        // state so /health and stealing_enabled() never claim a balancer
        // that does not exist
        let steal = steal && engines.len() >= 2;
        let shared = Arc::new(RouterShared::new(
            opts.stall_ms,
            opts.fault.as_ref().map(|p| p.arm()),
        ));
        // goodput control: each engine observes its own actuator cell;
        // the control thread (spawned below) writes all of them from the
        // sampled fleet state
        let ctl_cells: Vec<Arc<ControlCell>> = if opts.control == SpecControl::Goodput {
            engines.iter().map(|_| Arc::new(ControlCell::new())).collect()
        } else {
            Vec::new()
        };
        let cap_max = engines
            .iter()
            .map(|e| e.cfg.spec_k)
            .max()
            .unwrap_or(1)
            .max(1);
        let replicas: Vec<Replica> = engines
            .into_iter()
            .enumerate()
            .map(|(i, mut engine)| {
                if let Some(c) = ctl_cells.get(i) {
                    engine.set_control(c.clone());
                }
                let (tx, rx) = channel();
                let load = Arc::new(AtomicUsize::new(0));
                let cell = Arc::new(LoadCell::new(&engine));
                let alive = Arc::new(AtomicBool::new(true));
                let heartbeat = Arc::new(AtomicU64::new(0));
                let last_dispatch = Arc::new(AtomicU64::new(0));
                let load_t = load.clone();
                let cell_t = cell.clone();
                let alive_t = alive.clone();
                let hb_t = heartbeat.clone();
                let shared_t = shared.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("dsde-replica-{i}"))
                    .spawn(move || {
                        let result = catch_unwind(AssertUnwindSafe(move || {
                            replica_loop(engine, i, rx, load_t, cell_t, hb_t, shared_t);
                        }));
                        // dropping alive is the supervisor's death signal;
                        // it rescues our ledger entries from there
                        alive_t.store(false, Ordering::SeqCst);
                        if result.is_err() {
                            log_warn!(
                                "replica {i} panicked; supervisor will fail it over"
                            );
                        }
                    })
                    .expect("spawn replica thread");
                Replica {
                    tx,
                    load,
                    cell,
                    alive,
                    heartbeat,
                    last_dispatch,
                    thread: Mutex::new(Some(thread)),
                }
            })
            .collect();
        let steals = Arc::new(AtomicU64::new(0));
        let supervisor_stop = Arc::new(AtomicBool::new(false));
        let views: Vec<SupervisorView> = replicas
            .iter()
            .map(|r| SupervisorView {
                tx: r.tx.clone(),
                load: r.load.clone(),
                cell: r.cell.clone(),
                alive: r.alive.clone(),
                heartbeat: r.heartbeat.clone(),
                last_dispatch: r.last_dispatch.clone(),
            })
            .collect();
        let stop = supervisor_stop.clone();
        let stolen = steals.clone();
        let shared_s = shared.clone();
        let supervisor = std::thread::Builder::new()
            .name("dsde-balancer".to_string())
            .spawn(move || supervisor_loop(views, shared_s, steal, stop, stolen))
            .expect("spawn supervisor thread");
        let control = (opts.control == SpecControl::Goodput).then(|| {
            let export = Arc::new(ControlExport::default());
            let stop = Arc::new(AtomicBool::new(false));
            let taps: Vec<ControlTap> = replicas
                .iter()
                .zip(ctl_cells.iter())
                .map(|(r, actuator)| ControlTap {
                    cell: r.cell.clone(),
                    alive: r.alive.clone(),
                    actuator: actuator.clone(),
                })
                .collect();
            let cfg = ControlConfig {
                cap_max,
                ..Default::default()
            };
            let stop_t = stop.clone();
            let export_t = export.clone();
            let thread = std::thread::Builder::new()
                .name("dsde-spec-ctl".to_string())
                .spawn(move || control_loop(taps, cfg, stop_t, export_t))
                .expect("spawn control thread");
            ControlState {
                export,
                stop,
                thread: Mutex::new(Some(thread)),
            }
        });
        EngineRouter {
            replicas,
            policy,
            steal,
            rr_next: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            steals,
            supervisor_stop,
            supervisor: Mutex::new(Some(supervisor)),
            record: None,
            shared,
            control,
            limiter: opts
                .rate_limit
                .map(crate::server::limiter::TenantLimiter::new),
        }
    }

    /// The per-tenant admission limiter, when `--rate-limit` is set.
    /// Both front-ends consult it in the shared dispatch before a
    /// completion request reaches the engines.
    pub fn rate_limiter(&self) -> Option<&crate::server::limiter::TenantLimiter> {
        self.limiter.as_ref()
    }

    /// Install the request-record hook (the `--record` trace path).  Must
    /// be called before the router starts serving; every subsequent
    /// submission — blocking or streaming, from any front-end — fires it
    /// once with the id-assigned request.
    pub fn set_record_hook(&mut self, hook: RecordHook) {
        self.record = Some(hook);
    }

    /// Attach a write-ahead [`Journal`]: submissions are recorded through
    /// its hook (superseding any plain record hook) and completion
    /// markers are written as terminal events are delivered — from
    /// whichever thread delivers them, including failover paths.  Armed
    /// faults (if any) are threaded into the journal so `DropJournalSync`
    /// can bite.  Call before serving starts.
    pub fn set_journal(&mut self, journal: Arc<Journal>) {
        if let Some(f) = &self.shared.faults {
            journal.set_faults(f.clone());
        }
        self.record = Some(journal.hook());
        *self.shared.journal.lock().expect("journal lock") = Some(journal);
    }

    /// The active speculation-control mode (surfaced on `/health` and in
    /// `/v1/metrics` as `spec_control`).
    pub fn spec_control(&self) -> SpecControl {
        if self.control.is_some() {
            SpecControl::Goodput
        } else {
            SpecControl::Off
        }
    }

    /// Controller gauges `(current SL cap, total actuations, goodput
    /// EMA)`; `None` with control off.
    pub fn control_gauges(&self) -> Option<(usize, u64, f64)> {
        self.control
            .as_ref()
            .map(|c| (c.export.sl_cap(), c.export.adjustments(), c.export.goodput()))
    }

    /// Whether a record hook is installed (surfaced on `/health` so an
    /// operator can tell a trace is being captured).
    pub fn recording(&self) -> bool {
        self.record.is_some()
    }

    /// Number of engine replicas behind this router.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The dispatch policy in effect.
    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Whether the work-stealing half of the supervisor is active (false
    /// on a single-replica router even when stealing was requested).
    pub fn stealing_enabled(&self) -> bool {
        self.steal
    }

    /// Requests migrated between replicas by the supervisor so far.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::SeqCst)
    }

    /// Replicas declared failed (dead or wedged) so far.
    pub fn replica_failures(&self) -> u64 {
        self.shared.failures.load(Ordering::SeqCst)
    }

    /// Requests re-dispatched to a survivor after their replica failed.
    pub fn resubmissions(&self) -> u64 {
        self.shared.resubmitted.load(Ordering::SeqCst)
    }

    /// The injected per-connection accept delay, when a `SlowConn` fault
    /// is armed (front-ends sleep this long before serving a request).
    pub(crate) fn conn_delay(&self) -> Option<Duration> {
        self.shared.faults.as_ref().and_then(|f| f.conn_delay())
    }

    /// Current in-flight request count per replica.
    pub fn loads(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.load.load(Ordering::SeqCst))
            .collect()
    }

    /// Per-replica load gauges (KV occupancy + queue pressure + failure
    /// flag) as last published by the replica threads, with the channel
    /// backlog folded in — the data the KV-aware policy routes on.
    pub fn replica_loads(&self) -> Vec<ReplicaLoad> {
        self.replicas.iter().map(|r| r.cell.snapshot()).collect()
    }

    /// Total in-flight requests across replicas.
    pub fn in_flight(&self) -> usize {
        self.loads().iter().sum()
    }

    /// Pick a replica index for a request with the given projected token
    /// demand (prompt + output budget; only KvAware uses it).  Failed and
    /// dead replicas are skipped; if none are healthy the full set is
    /// used so dispatch still runs (and surfaces the error cleanly).
    fn pick(&self, candidate_tokens: usize) -> usize {
        let healthy: Vec<usize> = (0..self.replicas.len())
            .filter(|&i| {
                let r = &self.replicas[i];
                r.alive.load(Ordering::SeqCst) && !r.cell.is_failed()
            })
            .collect();
        let candidates = if healthy.is_empty() {
            (0..self.replicas.len()).collect()
        } else {
            healthy
        };
        match self.policy {
            RoutePolicy::RoundRobin => {
                candidates[self.rr_next.fetch_add(1, Ordering::SeqCst) % candidates.len()]
            }
            RoutePolicy::LeastLoaded => {
                let mut best = candidates[0];
                for &i in &candidates {
                    if self.replicas[i].load.load(Ordering::SeqCst)
                        < self.replicas[best].load.load(Ordering::SeqCst)
                    {
                        best = i;
                    }
                }
                best
            }
            RoutePolicy::KvAware => {
                let mut best = candidates[0];
                let mut best_headroom = isize::MIN;
                let mut best_load = usize::MAX;
                for &i in &candidates {
                    let r = &self.replicas[i];
                    let headroom = r.cell.kv_headroom(candidate_tokens);
                    let load = r.load.load(Ordering::SeqCst);
                    // most projected KV headroom wins; in-flight count
                    // breaks ties (equal-KV replicas degrade to
                    // least-loaded, e.g. uniform workloads)
                    if headroom > best_headroom
                        || (headroom == best_headroom && load < best_load)
                    {
                        best = i;
                        best_headroom = headroom;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// Register the request in the ledger and hand it to a replica,
    /// starting at `first` and falling back across the remaining healthy
    /// replicas if the send fails.  Returns false when no replica could
    /// accept it (the dropped reply surfaces as an error at the caller) —
    /// unless a concurrent failover already re-owned the request, in
    /// which case it is in good hands and true is returned.
    fn dispatch(&self, first: usize, req: Request, reply: ReplyTo) -> bool {
        let id = req.id;
        let n = self.replicas.len();
        let mut req = req;
        let mut reply = Some(reply);
        for off in 0..n {
            let idx = (first + off) % n;
            let replica = &self.replicas[idx];
            if off > 0
                && (!replica.alive.load(Ordering::SeqCst) || replica.cell.is_failed())
            {
                continue;
            }
            {
                let mut ledger = self.shared.ledger.lock().expect("ledger lock");
                ledger.insert(
                    id,
                    LedgerEntry {
                        req: req.clone(),
                        reply: reply.take().expect("reply in hand"),
                        replica: idx,
                        progressed: false,
                        enqueued: Instant::now(),
                    },
                );
            }
            replica.load.fetch_add(1, Ordering::SeqCst);
            replica.cell.on_enqueue(&req);
            replica
                .last_dispatch
                .store(self.shared.now_ms(), Ordering::SeqCst);
            match replica.tx.send(EngineMsg::Submit(req)) {
                Ok(()) => return true,
                Err(std::sync::mpsc::SendError(msg)) => {
                    // replica already gone; undo the accounting and try
                    // the next healthy one
                    dec_load(&replica.load);
                    let taken = {
                        let mut ledger = self.shared.ledger.lock().expect("ledger lock");
                        match ledger.get(&id) {
                            Some(e) if e.replica == idx => ledger.remove(&id),
                            _ => None,
                        }
                    };
                    let Some(entry) = taken else {
                        // a concurrent failover drained the dead replica's
                        // entries and already re-dispatched this request
                        return true;
                    };
                    replica.cell.on_dequeue(&entry.req);
                    reply = Some(entry.reply);
                    let EngineMsg::Submit(r) = msg else {
                        unreachable!("send returns the message it was given")
                    };
                    req = r;
                }
            }
        }
        false
    }

    /// Dispatch a request to a replica; returns the channel the finished
    /// result arrives on.  The router assigns globally unique request ids
    /// (any caller-provided id is overwritten).
    pub fn submit(&self, req: Request) -> Receiver<FinishedRequest> {
        let idx = self.pick(projected_tokens(&req));
        self.dispatch_to(idx, req, None)
    }

    /// Like [`EngineRouter::submit`], but the replica thread pokes `waker`
    /// after delivering the result — the event-loop front-end's
    /// nonblocking completion path (the loop `try_recv`s on wake instead
    /// of parking a thread in `recv`).
    pub fn submit_with_waker(
        &self,
        req: Request,
        waker: Arc<Waker>,
    ) -> Receiver<FinishedRequest> {
        let idx = self.pick(projected_tokens(&req));
        self.dispatch_to(idx, req, Some(waker))
    }

    /// Dispatch a request to a *specific* replica, bypassing the routing
    /// policy (ids are still router-assigned).  For diagnostics, benches,
    /// and imbalance tests — production traffic goes through
    /// [`EngineRouter::submit`].
    pub fn submit_to(&self, idx: usize, req: Request) -> Receiver<FinishedRequest> {
        self.dispatch_to(idx, req, None)
    }

    fn dispatch_to(
        &self,
        idx: usize,
        mut req: Request,
        waker: Option<Arc<Waker>>,
    ) -> Receiver<FinishedRequest> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let (rtx, rrx) = channel();
        self.dispatch(idx, req, ReplyTo::Blocking(Notify::new(rtx, waker)));
        rrx
    }

    /// Dispatch a request whose output is consumed incrementally: the
    /// returned channel yields one [`StreamEvent::Delta`] per engine step
    /// that accepted tokens for the request, then [`StreamEvent::Done`]
    /// with the finished-request summary, after which it closes.  Routing
    /// (policy, unique ids, load accounting) and drain semantics are
    /// identical to [`EngineRouter::submit`].
    pub fn submit_streaming(&self, req: Request) -> Receiver<StreamEvent> {
        self.submit_streaming_opts(req, None)
    }

    /// Like [`EngineRouter::submit_streaming`], but the replica thread
    /// pokes `waker` after every delta and after the terminal event — the
    /// event-loop front-end's nonblocking streaming path.
    pub fn submit_streaming_with_waker(
        &self,
        req: Request,
        waker: Arc<Waker>,
    ) -> Receiver<StreamEvent> {
        self.submit_streaming_opts(req, Some(waker))
    }

    fn submit_streaming_opts(
        &self,
        mut req: Request,
        waker: Option<Arc<Waker>>,
    ) -> Receiver<StreamEvent> {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let idx = self.pick(projected_tokens(&req));
        let (rtx, rrx) = channel();
        self.dispatch(idx, req, ReplyTo::Streaming(Notify::new(rtx, waker)));
        rrx
    }

    /// Install each replica's per-shard ring producers (one [`ShardTx`]
    /// per event-loop shard, outer index = replica) and its frame pool
    /// (stream frames are encoded into recycled pooled buffers on the
    /// replica thread).  Must be called before the front-end starts
    /// accepting: the attach message travels the same FIFO channel as
    /// submissions, so every subsequent
    /// [`EngineRouter::submit_streaming_ring`] finds the rings in place.
    pub(crate) fn attach_stream_shards(&self, per_replica: Vec<(Vec<ShardTx>, BufPool)>) {
        assert_eq!(
            per_replica.len(),
            self.replicas.len(),
            "one shard set per replica"
        );
        for (r, (shards, pool)) in self.replicas.iter().zip(per_replica) {
            let _ = r.tx.send(EngineMsg::AttachShards(shards, pool));
        }
    }

    /// Dispatch a streaming request whose deltas are delivered as
    /// preformatted NDJSON frames on `target`'s shard ring instead of an
    /// mpsc channel — the event-loop front-end's zero-channel streaming
    /// path.  Routing (policy, unique ids, load accounting, record hook)
    /// matches [`EngineRouter::submit_streaming`].  Returns false when no
    /// replica could accept it (no frame will ever arrive; the caller
    /// writes the aborted summary itself).
    pub(crate) fn submit_streaming_ring(&self, mut req: Request, target: RingTarget) -> bool {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(hook) = &self.record {
            hook(&req);
        }
        let idx = self.pick(projected_tokens(&req));
        self.dispatch(idx, req, ReplyTo::Ring(target))
    }

    /// Submit and block until the request completes.
    pub fn complete(&self, req: Request) -> Result<FinishedRequest> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("request dropped: router is shutting down"))
    }

    /// Per-replica metrics snapshots with the default percentile set
    /// (skips replicas that exited or were failed over).  Each reply is
    /// pre-reduced on the replica thread — O(#quantiles), never the full
    /// request window — so high-frequency scraping stays cheap.
    pub fn replica_metrics(&self) -> Vec<MetricsSnapshot> {
        self.replica_metrics_with(DEFAULT_QUANTILES)
    }

    /// Per-replica metrics snapshots carrying the requested percentiles.
    pub fn replica_metrics_with(&self, quantiles: &[f64]) -> Vec<MetricsSnapshot> {
        self.replica_metrics_opt(quantiles)
            .into_iter()
            .flatten()
            .collect()
    }

    /// Index-aligned per-replica snapshots.  A replica that is failed,
    /// dead, or does not answer inside [`METRICS_TIMEOUT`] (a wedged
    /// replica must not hang the metrics endpoint) is answered from its
    /// retained black box instead of a live scrape, so work it delivered
    /// before dying stays in fleet aggregates exactly once — the
    /// resubmitted remainder accrues only on its new owner.  `None` only
    /// for a replica with an empty black box and no live answer.
    fn replica_metrics_opt(&self, quantiles: &[f64]) -> Vec<Option<MetricsSnapshot>> {
        self.replicas
            .iter()
            .map(|r| -> Option<MetricsSnapshot> {
                let live = (|| {
                    if r.cell.is_failed() {
                        return None;
                    }
                    let (tx, rx) = channel();
                    r.tx.send(EngineMsg::Metrics(quantiles.to_vec(), tx)).ok()?;
                    rx.recv_timeout(METRICS_TIMEOUT).ok()
                })();
                live.or_else(|| Some(r.cell.retained_metrics()))
            })
            .collect()
    }

    /// Merge per-replica snapshots into one aggregate (counters summed,
    /// distributions merged exactly, percentiles taking the per-quantile
    /// maximum across replicas — see [`MetricsSnapshot::merge`]).
    fn merge_snapshots(per: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut iter = per.iter();
        let Some(first) = iter.next() else {
            return MetricsSnapshot::default();
        };
        let mut agg = first.clone();
        for m in iter {
            agg.merge(m);
        }
        agg
    }

    /// Metrics aggregated across all live replicas.
    pub fn aggregated_metrics(&self) -> MetricsSnapshot {
        Self::merge_snapshots(&self.replica_metrics())
    }

    /// The `/v1/metrics` payload: aggregate counters plus a per-replica
    /// summary, the routing configuration, and the recovery counters
    /// (`replica_failures`, `resubmitted`, `journal_lag`).
    ///
    /// The merged `throughput`/`goodput` divide by *summed* busy seconds
    /// (per-busy-second rates, flat in replica count); `fleet_throughput`
    /// divides total tokens by the fleet makespan (the slowest replica's
    /// busy time) and is the number that scales with replicas.
    pub fn metrics_json(&self) -> Json {
        let per = self.replica_metrics_opt(DEFAULT_QUANTILES);
        let merged: Vec<MetricsSnapshot> = per.iter().flatten().cloned().collect();
        let agg = Self::merge_snapshots(&merged);
        let makespan = merged.iter().map(|m| m.busy_time).fold(0.0f64, f64::max);
        let fleet_throughput = if makespan > 0.0 {
            agg.tokens_out as f64 / makespan
        } else {
            0.0
        };
        let loads = self.loads();
        let cells = self.replica_loads();
        let replicas: Vec<Json> = per
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let lc = cells.get(i).copied().unwrap_or_default();
                // a failed replica answers from its retained black box
                // (its delivered pre-failure work, counted exactly once);
                // `failed` tells the operator why the row is frozen
                let m = m.clone().unwrap_or_default();
                Json::obj()
                    .set("replica", i)
                    .set("failed", lc.failed)
                    .set("in_flight", *loads.get(i).unwrap_or(&0))
                    .set("tokens_out", m.tokens_out)
                    .set("requests", m.completed)
                    .set("throughput", m.throughput())
                    .set("busy_time", m.busy_time)
                    .set("preemptions", m.preemptions)
                    .set("kv_used_blocks", lc.kv_used_blocks)
                    .set("kv_free_blocks", lc.kv_free_blocks)
                    .set("queued_requests", lc.queued_requests)
                    .set("queued_prompt_tokens", lc.queued_prompt_tokens)
            })
            .collect();
        let journal_lag = self
            .shared
            .journal()
            .map(|j| j.lag())
            .unwrap_or(0);
        // controller gauges: with control off the cap is pinned at 0
        // ("uncapped by the controller") and goodput_est falls back to
        // the merged all-time goodput
        let (spec_control, sl_cap_current, control_adjustments, goodput_est) =
            match &self.control {
                Some(c) => (
                    SpecControl::Goodput.name(),
                    c.export.sl_cap(),
                    c.export.adjustments(),
                    c.export.goodput(),
                ),
                None => (SpecControl::Off.name(), 0, 0, agg.goodput()),
            };
        agg.to_json()
            .set("route_policy", self.policy.name())
            .set("replica_count", self.replicas.len())
            .set("work_stealing", self.steal)
            .set("steals", self.steals())
            .set("replica_failures", self.replica_failures())
            .set("resubmitted", self.resubmissions())
            .set("journal_lag", journal_lag)
            .set("fleet_makespan", makespan)
            .set("fleet_throughput", fleet_throughput)
            .set("spec_control", spec_control)
            .set("sl_cap_current", sl_cap_current)
            .set("control_adjustments", control_adjustments)
            .set("goodput_est", goodput_est)
            .set(
                "rate_limit",
                match &self.limiter {
                    Some(l) => l.to_json(),
                    None => Json::Null,
                },
            )
            .set("replicas", replicas)
    }

    /// Stop the supervisor (and the control thread, if any) and wait for
    /// them — always before drain/abort so no steal, failover, or
    /// actuation can race a replica teardown.  Idempotent.
    fn stop_supervisor(&self) {
        self.supervisor_stop.store(true, Ordering::SeqCst);
        let handle = self.supervisor.lock().expect("supervisor lock").take();
        if let Some(t) = handle {
            let _ = t.join();
        }
        if let Some(c) = &self.control {
            c.stop.store(true, Ordering::SeqCst);
            let handle = c.thread.lock().expect("control lock").take();
            if let Some(t) = handle {
                let _ = t.join();
            }
        }
    }

    /// Deliver an aborted terminal to every request still in the ledger —
    /// the last line of the no-hung-client guarantee: after teardown,
    /// entries can remain only for replicas that died before the
    /// supervisor rescued them.  Ring streams need no action here: their
    /// dead producers' closed rings make the shard synthesize the
    /// terminal.
    fn finish_stranded(&self) {
        let stranded: Vec<LedgerEntry> = {
            let mut ledger = self.shared.ledger.lock().expect("ledger lock");
            ledger.drain().map(|(_, e)| e).collect()
        };
        if stranded.is_empty() {
            return;
        }
        let journal = self.shared.journal();
        let mut ring_aborts = Vec::new();
        for entry in stranded {
            deliver_abort(entry, &journal, &mut ring_aborts);
        }
        // ring_aborts intentionally dropped: every producer thread has
        // exited, so ring-close synthesis covers those streams
    }

    /// Graceful drain: every replica finishes its in-flight work (clients
    /// receive their completions), then the threads exit.  Requests
    /// stranded by a dead replica get aborted terminals.  Idempotent.
    pub fn shutdown(&self) {
        self.stop_supervisor();
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Drain);
        }
        self.join();
        self.finish_stranded();
    }

    /// Hard stop: in-flight work is aborted (`FinishReason::Aborted`).
    pub fn abort(&self) {
        self.stop_supervisor();
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Abort);
        }
        self.join();
        self.finish_stranded();
    }

    fn join(&self) {
        for r in &self.replicas {
            let handle = r.thread.lock().expect("replica lock").take();
            if let Some(t) = handle {
                let _ = t.join();
            }
        }
    }
}

impl Drop for EngineRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::engine::request::{FinishReason, SamplingParams};
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::sim::regime::DatasetProfile;

    fn sim_engines(n: usize) -> Vec<Engine> {
        (0..n)
            .map(|i| {
                let cfg = EngineConfig {
                    max_batch: 4,
                    max_len: 4096,
                    policy: SlPolicyKind::Static(4),
                    seed: 10 + i as u64,
                    ..Default::default()
                };
                let model = SimModel::new(
                    SimPairKind::LlamaLike,
                    DatasetProfile::cnndm(),
                    10 + i as u64,
                );
                Engine::new(cfg, Box::new(model))
            })
            .collect()
    }

    fn req(max_tokens: usize) -> Request {
        Request::new(
            0,
            vec![65; 24],
            SamplingParams {
                max_tokens,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_replica_roundtrip() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        router.shutdown();
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let router = EngineRouter::new(sim_engines(3), RoutePolicy::RoundRobin);
        assert_eq!(router.pick(24), 0);
        assert_eq!(router.pick(24), 1);
        assert_eq!(router.pick(24), 2);
        assert_eq!(router.pick(24), 0);
        router.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        // manufacture imbalance: replica 0 busy with 3 in-flight
        router.replicas[0].load.store(3, Ordering::SeqCst);
        assert_eq!(router.pick(24), 1);
        router.replicas[0].load.store(0, Ordering::SeqCst);
        router.shutdown();
    }

    #[test]
    fn kv_aware_prefers_replica_with_block_headroom() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        // manufacture KV pressure on replica 0: almost no free blocks
        router.replicas[0]
            .cell
            .kv_free_blocks
            .store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        // flip it: replica 1 is the full one now
        router.replicas[0]
            .cell
            .kv_free_blocks
            .store(4096, Ordering::SeqCst);
        router.replicas[1]
            .cell
            .kv_free_blocks
            .store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 0);
        router.shutdown();
    }

    #[test]
    fn kv_aware_counts_queued_and_channel_backlog() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        // equal free blocks, but replica 0 has a deep projected queue
        router.replicas[0]
            .cell
            .queued_prompt_tokens
            .store(60_000, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        router.replicas[0]
            .cell
            .queued_prompt_tokens
            .store(0, Ordering::SeqCst);
        router.replicas[1]
            .cell
            .channel_tokens
            .store(60_000, Ordering::SeqCst);
        assert_eq!(router.pick(64), 0);
        router.replicas[1].cell.channel_tokens.store(0, Ordering::SeqCst);
        // all equal: tie breaks by in-flight count
        router.replicas[0].load.store(2, Ordering::SeqCst);
        assert_eq!(router.pick(64), 1);
        router.replicas[0].load.store(0, Ordering::SeqCst);
        router.shutdown();
    }

    #[test]
    fn kv_aware_router_completes_everything() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::KvAware);
        let rxs: Vec<_> = (0..10).map(|_| router.submit(req(8))).collect();
        for rx in rxs {
            let fin = rx.recv().expect("kv-aware routing must not drop work");
            assert_eq!(fin.output.len(), 8);
        }
        assert_eq!(router.in_flight(), 0);
        let agg = router.aggregated_metrics();
        assert_eq!(agg.completed, 10);
        router.shutdown();
    }

    #[test]
    fn submit_to_targets_specific_replica() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..4).map(|_| router.submit_to(1, req(6))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().output.len(), 6);
        }
        let per = router.replica_metrics();
        assert_eq!(per[0].completed, 0, "replica 0 must stay untouched");
        assert_eq!(per[1].completed, 4);
        router.shutdown();
    }

    #[test]
    fn work_stealing_rebalances_a_hot_replica() {
        // all work lands on replica 0; the supervisor must move some of
        // the queue to idle replica 1, and nothing may be lost or
        // duplicated.  Whether a steal fires in time is wall-clock
        // dependent (the sim burst races the 200µs supervisor poll), so
        // retry with fresh routers; the no-loss/no-dup invariants are
        // asserted every attempt regardless.
        let n = 24;
        for attempt in 0..5 {
            let router = EngineRouter::with_options(
                sim_engines(2),
                RoutePolicy::RoundRobin,
                true,
            );
            let rxs: Vec<_> = (0..n).map(|_| router.submit_to(0, req(256))).collect();
            let mut ids = Vec::new();
            for rx in rxs {
                let fin = rx.recv().expect("stolen or local, every request resolves");
                assert_eq!(fin.reason, FinishReason::MaxTokens);
                assert_eq!(fin.output.len(), 256);
                ids.push(fin.id);
            }
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "no duplicate or lost completions");
            assert_eq!(router.in_flight(), 0);
            let stolen = router.steals();
            let per = router.replica_metrics();
            assert_eq!(per.iter().map(|m| m.completed).sum::<u64>(), n as u64);
            router.shutdown();
            if stolen > 0 {
                assert!(
                    per.iter().all(|m| m.completed > 0),
                    "both replicas must execute stolen work: {:?}",
                    per.iter().map(|m| m.completed).collect::<Vec<_>>()
                );
                return;
            }
            // burst drained before the supervisor got scheduled; try again
            eprintln!("attempt {attempt}: no steal fired, retrying");
        }
        panic!("supervisor never migrated work across 5 hot-replica bursts");
    }

    #[test]
    fn ids_are_globally_unique_across_replicas() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..10).map(|_| router.submit(req(4))).collect();
        let mut ids: Vec<u64> = rxs.into_iter().map(|rx| rx.recv().unwrap().id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        router.shutdown();
    }

    #[test]
    fn graceful_shutdown_completes_in_flight_work() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..6).map(|_| router.submit(req(32))).collect();
        router.shutdown(); // drain: all six must still complete normally
        for rx in rxs {
            let fin = rx.recv().expect("drained request must complete");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 32);
        }
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn abort_delivers_aborted_results() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..3).map(|_| router.submit(req(100_000))).collect();
        router.abort();
        for rx in rxs {
            let fin = rx.recv().expect("aborted request still resolves");
            assert_eq!(fin.reason, FinishReason::Aborted);
        }
    }

    #[test]
    fn unfittable_prompt_is_aborted_and_replica_stays_alive() {
        // KV capacity: 8 blocks * 16 tokens = 128 slots; a 200-token prompt
        // can never be admitted.  The replica must abort it (not busy-spin)
        // and keep serving subsequent requests.
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            kv_blocks: 8,
            policy: SlPolicyKind::Static(4),
            seed: 5,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 5);
        let router = EngineRouter::new(
            vec![Engine::new(cfg, Box::new(model))],
            RoutePolicy::RoundRobin,
        );
        // queue a servable request BEHIND the poison head before the
        // replica reacts: only the head may be aborted, not its followers
        let poisoned_rx =
            router.submit(Request::new(0, vec![65; 200], SamplingParams::default()));
        let behind_rx = router.submit(req(8));
        let poisoned = poisoned_rx.recv().expect("wedged request must resolve");
        assert_eq!(poisoned.reason, FinishReason::Aborted);
        let behind = behind_rx.recv().expect("follower must survive the abort");
        assert_eq!(behind.reason, FinishReason::MaxTokens);
        assert_eq!(behind.output.len(), 8);
        // the replica is unwedged and serves fresh traffic too
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn streaming_deltas_concatenate_to_full_output() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let rx = router.submit_streaming(req(16));
        let mut tokens = Vec::new();
        let mut last_t = f64::NEG_INFINITY;
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Delta { tokens: t, t: at } => {
                    assert!(at >= last_t, "deltas must arrive in clock order");
                    assert!(!t.is_empty());
                    last_t = at;
                    tokens.extend(t);
                }
                StreamEvent::Done(fin) => done = Some(fin),
            }
        }
        // the channel closed right after the terminal event
        let fin = done.expect("stream must end with Done");
        assert_eq!(fin.reason, FinishReason::MaxTokens);
        assert_eq!(tokens, fin.output, "deltas must concatenate to the output");
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn ring_streaming_delivers_ordered_frames_with_terminal() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        let (tx, mut rx) = spsc::ring(STREAM_RING_CAP);
        let waker = Arc::new(Waker::new().expect("waker"));
        router.attach_stream_shards(vec![(
            vec![ShardTx::new(tx, waker)],
            BufPool::new(STREAM_RING_CAP),
        )]);
        let target = RingTarget { shard: 0, conn: 42 };
        assert!(router.submit_streaming_ring(req(16), target));
        // play the shard loop: drain the ring until the terminal frame
        let mut frames: Vec<StreamFrame> = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while !frames.last().is_some_and(|f| f.done) {
            match rx.try_pop() {
                Some(f) => frames.push(f),
                None => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "ring stream must terminate"
                    );
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        assert!(frames.len() >= 2, "deltas then the terminal frame");
        assert!(frames.iter().all(|f| f.conn == 42));
        assert!(frames[..frames.len() - 1].iter().all(|f| !f.done));
        let last = frames.last().unwrap();
        assert!(
            last.bytes.ends_with(b"0\r\n\r\n"),
            "terminal frame carries the chunked-body terminator"
        );
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn ring_consumer_hangup_does_not_wedge_replica() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        // tiny ring: the stream overflows it immediately, and then the
        // consumer vanishes (shard loop death) mid-stream
        let (tx, rx) = spsc::ring(2);
        let waker = Arc::new(Waker::new().expect("waker"));
        router.attach_stream_shards(vec![(
            vec![ShardTx::new(tx, waker)],
            BufPool::new(STREAM_RING_CAP),
        )]);
        assert!(router.submit_streaming_ring(req(64), RingTarget { shard: 0, conn: 1 }));
        drop(rx);
        // the replica discards undeliverable frames and keeps serving
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        router.shutdown();
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn streaming_subscriber_hangup_does_not_wedge_replica() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        drop(router.submit_streaming(req(64))); // client vanished immediately
        // the replica keeps serving fresh traffic and load drains to zero
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        router.shutdown();
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn submit_after_shutdown_errors_cleanly() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        router.shutdown();
        assert!(router.complete(req(4)).is_err());
        assert_eq!(router.in_flight(), 0);
    }

    #[test]
    fn aggregated_metrics_sum_replica_counters() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let rxs: Vec<_> = (0..8).map(|_| router.submit(req(12))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let per = router.replica_metrics();
        assert_eq!(per.len(), 2);
        let agg = router.aggregated_metrics();
        assert_eq!(
            agg.tokens_out,
            per.iter().map(|m| m.tokens_out).sum::<u64>()
        );
        assert_eq!(agg.completed, 8);
        // round-robin with blocking-free submission: both replicas worked
        assert!(per.iter().all(|m| m.completed == 4));
        router.shutdown();
    }

    #[test]
    fn record_hook_sees_every_submission_with_assigned_ids() {
        let seen: Arc<Mutex<Vec<(u64, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let mut router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let sink = seen.clone();
        router.set_record_hook(Box::new(move |r| {
            sink.lock().unwrap().push((r.id, r.prompt.len()));
        }));
        let rx1 = router.submit(req(4));
        let rx2 = router.submit_streaming(req(6));
        rx1.recv().unwrap();
        for _ in rx2 {}
        let seen = seen.lock().unwrap().clone();
        assert_eq!(seen.len(), 2, "blocking AND streaming submissions fire");
        assert_eq!(seen[0], (1, 24), "hook sees the router-assigned id");
        assert_eq!(seen[1], (2, 24));
        router.shutdown();
    }

    #[test]
    fn metrics_json_has_aggregate_and_per_replica_views() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::LeastLoaded);
        let fin = router.complete(req(6)).unwrap();
        assert_eq!(fin.output.len(), 6);
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"replica_count\":2"), "{s}");
        assert!(s.contains("\"route_policy\":\"least-loaded\""), "{s}");
        assert!(s.contains("\"replicas\":["), "{s}");
        assert!(s.contains("block_efficiency"), "{s}");
        router.shutdown();
    }

    // --- crash recovery ---

    /// Synthetic supervisor fixture: real load cells over sim engines,
    /// plain channels standing in for replica threads, so the steal/fail
    /// paths can be driven deterministically (receivers dropped = dead
    /// replica).
    struct Fixture {
        shared: Arc<RouterShared>,
        views: Vec<SupervisorView>,
        rxs: Vec<Option<Receiver<EngineMsg>>>,
    }

    fn fixture(n: usize) -> Fixture {
        let engines = sim_engines(n);
        let mut rxs = Vec::new();
        let views: Vec<SupervisorView> = engines
            .iter()
            .map(|e| {
                let (tx, rx) = channel();
                rxs.push(Some(rx));
                SupervisorView {
                    tx,
                    load: Arc::new(AtomicUsize::new(0)),
                    cell: Arc::new(LoadCell::new(e)),
                    alive: Arc::new(AtomicBool::new(true)),
                    heartbeat: Arc::new(AtomicU64::new(0)),
                    last_dispatch: Arc::new(AtomicU64::new(0)),
                }
            })
            .collect();
        // engines only seeded the load cells; the fixture drives the
        // supervisor paths directly
        drop(engines);
        Fixture {
            shared: Arc::new(RouterShared::new(10_000, None)),
            views,
            rxs,
        }
    }

    /// Insert `count` ledger entries owned by `replica`, returning the
    /// blocking reply receivers (ids are 1-based).
    fn seed_ledger(
        fx: &Fixture,
        replica: usize,
        count: u64,
    ) -> (Vec<Request>, Vec<Receiver<FinishedRequest>>) {
        let mut reqs = Vec::new();
        let mut crxs = Vec::new();
        for k in 0..count {
            let mut r = req(8);
            r.id = k + 1;
            let (ctx, crx) = channel();
            crxs.push(crx);
            fx.shared.ledger.lock().unwrap().insert(
                r.id,
                LedgerEntry {
                    req: r.clone(),
                    reply: ReplyTo::Blocking(Notify::new(ctx, None)),
                    replica,
                    progressed: false,
                    enqueued: Instant::now(),
                },
            );
            reqs.push(r);
        }
        (reqs, crxs)
    }

    #[test]
    fn stolen_batch_survives_thief_death() {
        // regression for the balancer thief-gone edge: a steal batch whose
        // thief died mid-handoff must land on another live replica, not be
        // dropped on the floor
        let mut fx = fixture(3);
        let (batch, _crxs) = seed_ledger(&fx, 0, 2);
        fx.rxs[0] = None; // victim died after answering the steal
        fx.rxs[1] = None; // thief died before the handoff
        let placed = place_stolen(batch, &[1, 0, 2], &fx.views, &fx.shared);
        assert_eq!(placed, Some(2), "batch must land on the live replica");
        let msg = fx.rxs[2]
            .as_ref()
            .unwrap()
            .try_recv()
            .expect("live replica receives the batch");
        let EngineMsg::SubmitStolen(b) = msg else {
            panic!("expected SubmitStolen");
        };
        let mut ids: Vec<u64> = b.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        let ledger = fx.shared.ledger.lock().unwrap();
        assert_eq!(ledger.len(), 2);
        assert!(
            ledger.values().all(|e| e.replica == 2),
            "ownership must follow the batch"
        );
        assert_eq!(fx.views[2].load.load(Ordering::SeqCst), 2);
        assert_eq!(fx.views[1].load.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn stolen_batch_aborts_cleanly_when_every_replica_is_gone() {
        let mut fx = fixture(3);
        let (batch, crxs) = seed_ledger(&fx, 0, 2);
        for rx in fx.rxs.iter_mut() {
            *rx = None; // the whole fleet is dead
        }
        let placed = place_stolen(batch, &[1, 0, 2], &fx.views, &fx.shared);
        assert_eq!(placed, None);
        assert!(
            fx.shared.ledger.lock().unwrap().is_empty(),
            "aborted entries must leave the ledger"
        );
        for crx in crxs {
            let fin = crx.recv().expect("client gets a terminal event, not a hang");
            assert_eq!(fin.reason, FinishReason::Aborted);
        }
    }

    #[test]
    fn fail_replica_resubmits_to_survivors_with_accrued_wait() {
        let fx = fixture(2);
        let (_reqs, _crxs) = seed_ledger(&fx, 0, 3);
        fx.views[0].load.store(3, Ordering::SeqCst);
        fail_replica(0, &fx.views, &fx.shared);
        assert!(fx.views[0].cell.is_failed());
        assert_eq!(fx.shared.failures.load(Ordering::SeqCst), 1);
        assert_eq!(fx.shared.resubmitted.load(Ordering::SeqCst), 3);
        assert_eq!(fx.views[0].load.load(Ordering::SeqCst), 0);
        assert_eq!(fx.views[1].load.load(Ordering::SeqCst), 3);
        let mut rescued = 0;
        while let Ok(msg) = fx.rxs[1].as_ref().unwrap().try_recv() {
            let EngineMsg::Submit(r) = msg else {
                panic!("expected Submit resubmissions");
            };
            assert!(r.waited >= 0.0);
            rescued += 1;
        }
        assert_eq!(rescued, 3);
        let ledger = fx.shared.ledger.lock().unwrap();
        assert_eq!(ledger.len(), 3, "rescued entries stay in the ledger");
        assert!(ledger.values().all(|e| e.replica == 1));
    }

    #[test]
    fn fail_replica_aborts_progressed_streams_and_everything_without_survivors() {
        let fx = fixture(1);
        // one progressed stream: its bytes are on the wire, so it must be
        // aborted (never replayed), survivors or not
        let (ctx, crx) = channel();
        let mut r = req(8);
        r.id = 7;
        fx.shared.ledger.lock().unwrap().insert(
            7,
            LedgerEntry {
                req: r.clone(),
                reply: ReplyTo::Streaming(Notify::new(ctx, None)),
                replica: 0,
                progressed: true,
                enqueued: Instant::now(),
            },
        );
        fail_replica(0, &fx.views, &fx.shared);
        let ev = crx.recv().expect("stream gets its terminal event");
        let StreamEvent::Done(fin) = ev else {
            panic!("expected the terminal Done");
        };
        assert_eq!(fin.reason, FinishReason::Aborted);
        assert!(crx.recv().is_err(), "exactly one terminal event");
        assert!(fx.shared.ledger.lock().unwrap().is_empty());
    }

    #[test]
    fn injected_kill_fails_over_to_survivor() {
        // replica 0 is killed at t=0; everything routed at it must still
        // complete on replica 1 (via dispatch fallback or supervisor
        // rescue, depending on timing — the guarantee is the same)
        let plan = FaultPlan::parse("kill:0@0", 2).expect("plan parses");
        let router = EngineRouter::with_router_options(
            sim_engines(2),
            RoutePolicy::RoundRobin,
            false,
            RouterOptions {
                stall_ms: 5_000,
                fault: Some(plan),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..6).map(|_| router.submit_to(0, req(16))).collect();
        for rx in rxs {
            let fin = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("request must complete on the survivor");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 16);
        }
        // the kill always lands (idle replicas poll when faults are armed)
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while router.replica_failures() == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "supervisor must detect the killed replica"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"replica_failures\":1"), "{s}");
        assert!(s.contains("\"failed\":true"), "{s}");
        router.shutdown();
    }

    #[test]
    fn injected_stall_triggers_wedge_detection_and_rescue() {
        // replica 0 stalls for 2s starting at t=0 with a 100ms stall
        // window: the supervisor must declare it wedged and rescue its
        // queued work long before the stall ends
        let plan = FaultPlan::parse("stall:0@0+2000", 2).expect("plan parses");
        let router = EngineRouter::with_router_options(
            sim_engines(2),
            RoutePolicy::RoundRobin,
            false,
            RouterOptions {
                stall_ms: 100,
                fault: Some(plan),
                ..Default::default()
            },
        );
        let start = std::time::Instant::now();
        let rxs: Vec<_> = (0..4).map(|_| router.submit_to(0, req(16))).collect();
        for rx in rxs {
            let fin = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("stalled replica's work must be rescued");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 16);
        }
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "rescue must beat the stall, not wait it out"
        );
        assert_eq!(router.replica_failures(), 1);
        assert!(router.resubmissions() >= 1);
        router.shutdown();
    }

    #[test]
    fn journal_records_submits_and_completion_markers() {
        let path = std::env::temp_dir()
            .join(format!("dsde-router-journal-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let journal = Arc::new(Journal::create(&path, "test").expect("journal"));
        let mut router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        router.set_journal(journal.clone());
        assert!(router.recording());
        let fin = router.complete(req(8)).unwrap();
        router.shutdown();
        journal.sync();
        let state = crate::server::journal::load(&path).expect("journal loads");
        assert_eq!(state.submits.len(), 1);
        assert_eq!(
            state.completed.get(&fin.id).map(String::as_str),
            Some("max_tokens")
        );
        assert!(state.unfinished().is_empty(), "completed work is not replayed");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pick_skips_failed_replicas() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        router.replicas[0].cell.mark_failed();
        for _ in 0..4 {
            assert_eq!(router.pick(24), 1, "routing must avoid failed replicas");
        }
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"failed\":true"), "{s}");
        router.shutdown();
    }

    #[test]
    fn metrics_json_reports_recovery_counters() {
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"replica_failures\":0"), "{s}");
        assert!(s.contains("\"resubmitted\":0"), "{s}");
        assert!(s.contains("\"journal_lag\":0"), "{s}");
        assert!(s.contains("\"failed\":false"), "{s}");
        router.shutdown();
    }

    // --- closed-loop speculation control ---

    #[test]
    fn control_off_exports_neutral_gauges() {
        let router = EngineRouter::new(sim_engines(1), RoutePolicy::RoundRobin);
        assert_eq!(router.spec_control(), SpecControl::Off);
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"spec_control\":\"off\""), "{s}");
        assert!(s.contains("\"sl_cap_current\":0"), "{s}");
        assert!(s.contains("\"control_adjustments\":0"), "{s}");
        assert!(s.contains("\"goodput_est\""), "{s}");
        router.shutdown();
    }

    #[test]
    fn goodput_control_serves_and_exports_gauges() {
        let router = EngineRouter::with_router_options(
            sim_engines(2),
            RoutePolicy::RoundRobin,
            false,
            RouterOptions {
                control: SpecControl::Goodput,
                ..Default::default()
            },
        );
        assert_eq!(router.spec_control(), SpecControl::Goodput);
        let rxs: Vec<_> = (0..8).map(|_| router.submit(req(32))).collect();
        for rx in rxs {
            let fin = rx.recv().expect("controlled router must still serve");
            assert_eq!(fin.reason, FinishReason::MaxTokens);
            assert_eq!(fin.output.len(), 32);
        }
        // give the 20ms control loop at least one tick to publish
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while router.metrics_json().to_string().contains("\"sl_cap_current\":0")
        {
            assert!(
                std::time::Instant::now() < deadline,
                "control loop must publish its gauges"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let s = router.metrics_json().to_string();
        assert!(s.contains("\"spec_control\":\"goodput\""), "{s}");
        router.shutdown();
    }

    #[test]
    fn failed_replica_metrics_come_from_black_box() {
        // the retained snapshot must answer for a failed replica so its
        // delivered pre-failure work stays in the fleet aggregate
        let router = EngineRouter::new(sim_engines(2), RoutePolicy::RoundRobin);
        let fin = router.complete(req(8)).unwrap();
        assert_eq!(fin.output.len(), 8);
        // seed the black box by hand (the amortized in-loop refresh may
        // not have fired yet for this short run)
        let mut boxed = MetricsSnapshot::default();
        boxed.completed = 1;
        boxed.completed_tokens = 8;
        router.replicas[0].cell.record_metrics(boxed);
        router.replicas[0].cell.mark_failed();
        let per = router.replica_metrics_opt(DEFAULT_QUANTILES);
        let frozen = per[0].as_ref().expect("black box answers for the dead");
        assert_eq!(frozen.completed, 1);
        assert_eq!(frozen.completed_tokens, 8);
        let agg = router.aggregated_metrics();
        assert!(agg.completed >= 1, "pre-failure work stays aggregated");
        router.shutdown();
    }
}
