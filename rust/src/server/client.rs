//! Load-generating HTTP client for the completions API (used by the
//! `serve_http` example and the serving benchmarks), including a streaming
//! consumer that measures client-observed time-to-first-token.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One completed load-test call.
#[derive(Clone, Debug)]
pub struct CallResult {
    /// HTTP status code.
    pub status: u16,
    /// Wall seconds from connect to last byte.
    pub wall_s: f64,
    /// Parsed JSON response body.
    pub body: Json,
}

/// One delta line consumed from a streamed completion.
#[derive(Clone, Debug)]
pub struct StreamDelta {
    /// Decoded text of this delta's tokens.
    pub text: String,
    /// Number of tokens in this delta.
    pub tokens: usize,
    /// Client wall seconds (since the request was sent) when the delta
    /// arrived.
    pub at_s: f64,
}

/// A fully consumed streaming completion.
#[derive(Clone, Debug)]
pub struct StreamResult {
    /// HTTP status code.
    pub status: u16,
    /// Total wall seconds from send to stream end.
    pub wall_s: f64,
    /// Wall seconds until the first delta arrived — client-observed TTFT.
    pub ttft_s: f64,
    /// Every delta line, in arrival order.
    pub deltas: Vec<StreamDelta>,
    /// The terminal `"done": true` line (finish reason + server metrics).
    pub finale: Json,
}

impl StreamResult {
    /// Concatenated text across all deltas (equals the non-streaming
    /// completion text for the same seeded request).
    pub fn text(&self) -> String {
        self.deltas.iter().map(|d| d.text.as_str()).collect()
    }

    /// Total tokens across all deltas.
    pub fn tokens(&self) -> usize {
        self.deltas.iter().map(|d| d.tokens).sum()
    }
}

/// Issue one blocking completions call.
pub fn complete(
    addr: &str,
    prompt: &str,
    max_tokens: usize,
    temperature: f64,
) -> Result<CallResult> {
    let body = Json::obj()
        .set("prompt", prompt)
        .set("max_tokens", max_tokens)
        .set("temperature", temperature)
        .to_string();
    let req = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: dsde\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let wall_s = t0.elapsed().as_secs_f64();
    parse_response(&resp, wall_s)
}

/// Issue one streaming completions call (`"stream": true`) and consume the
/// chunked NDJSON response incrementally, timestamping each delta — the
/// client-side TTFT/ITL measurement path.
pub fn complete_streaming(
    addr: &str,
    prompt: &str,
    max_tokens: usize,
    temperature: f64,
) -> Result<StreamResult> {
    let body = Json::obj()
        .set("prompt", prompt)
        .set("max_tokens", max_tokens)
        .set("temperature", temperature)
        .set("stream", true)
        .to_string();
    let req = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: dsde\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let t0 = Instant::now();
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    writer.write_all(req.as_bytes())?;
    let mut reader = BufReader::new(stream);

    // status line + headers
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line: {line:?}"))?;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("transfer-encoding")
                && v.trim().eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }
    if !chunked {
        return Err(anyhow!("server did not stream (status {status})"));
    }

    // chunk loop: hex size line, `size` data bytes, CRLF.  Chunk framing
    // carries no message semantics (a proxy may re-chunk the body), so
    // NDJSON lines — and any UTF-8 sequence a boundary may split — are
    // reassembled in a byte carry buffer before parsing.
    let mut deltas = Vec::new();
    let mut finale: Option<Json> = None;
    let mut ttft_s = 0.0;
    let mut carry: Vec<u8> = Vec::new();
    loop {
        let mut size_line = String::new();
        if reader.read_line(&mut size_line)? == 0 {
            break; // connection closed without the zero chunk
        }
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| anyhow!("bad chunk size line: {size_line:?}"))?;
        if size == 0 {
            break;
        }
        let mut buf = vec![0u8; size + 2]; // data + trailing CRLF
        reader.read_exact(&mut buf)?;
        let at_s = t0.elapsed().as_secs_f64();
        carry.extend_from_slice(&buf[..size]);
        while let Some(pos) = carry.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = carry.drain(..=pos).collect();
            let line = std::str::from_utf8(&line_bytes)
                .map_err(|e| anyhow!("stream line not utf8: {e}"))?
                .trim();
            if line.is_empty() {
                continue;
            }
            let j = Json::parse(line).map_err(|e| anyhow!("stream json: {e}"))?;
            if j.get("done").and_then(|d| d.as_bool()).unwrap_or(false) {
                finale = Some(j);
            } else {
                if deltas.is_empty() {
                    ttft_s = at_s;
                }
                deltas.push(StreamDelta {
                    text: j
                        .get("text")
                        .and_then(|t| t.as_str())
                        .unwrap_or("")
                        .to_string(),
                    tokens: j.get("tokens").and_then(|t| t.as_usize()).unwrap_or(0),
                    at_s,
                });
            }
        }
    }
    // a well-behaved server always ends with a `"done": true` line (even
    // on abort); its absence means the stream was truncated mid-flight —
    // surface that instead of returning a partial completion as success
    let finale = finale.ok_or_else(|| {
        anyhow!(
            "stream truncated: connection ended after {} delta(s) without a \
             terminal event",
            deltas.len()
        )
    })?;
    Ok(StreamResult {
        status,
        wall_s: t0.elapsed().as_secs_f64(),
        ttft_s,
        deltas,
        finale,
    })
}

/// Fetch the metrics snapshot.
pub fn metrics(addr: &str) -> Result<Json> {
    let req = "GET /v1/metrics HTTP/1.1\r\nHost: dsde\r\nConnection: close\r\n\r\n";
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    Ok(parse_response(&resp, 0.0)?.body)
}

fn parse_response(resp: &str, wall_s: f64) -> Result<CallResult> {
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response: {resp:.60}"))?;
    let body_text = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .unwrap_or("{}");
    let body = Json::parse(body_text).map_err(|e| anyhow!("body parse: {e}"))?;
    Ok(CallResult {
        status,
        wall_s,
        body,
    })
}

/// Closed-loop load: `concurrency` worker threads each issue
/// `calls_per_worker` sequential completions.  Returns all call results.
pub fn closed_loop(
    addr: &str,
    prompts: Vec<String>,
    max_tokens: usize,
    temperature: f64,
    concurrency: usize,
) -> Vec<CallResult> {
    let addr = addr.to_string();
    let chunks: Vec<Vec<String>> = (0..concurrency)
        .map(|w| {
            prompts
                .iter()
                .skip(w)
                .step_by(concurrency)
                .cloned()
                .collect()
        })
        .collect();
    let mut handles = Vec::new();
    for chunk in chunks {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for p in chunk {
                if let Ok(r) = complete(&addr, &p, max_tokens, temperature) {
                    out.push(r);
                }
            }
            out
        }));
    }
    handles
        .into_iter()
        .flat_map(|h| h.join().unwrap_or_default())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::engine::engine::Engine;
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::server::http::serve;
    use crate::sim::regime::DatasetProfile;

    fn sim_server() -> crate::server::http::ServerHandle {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            policy: SlPolicyKind::Static(4),
            seed: 2,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 2);
        serve(Engine::new(cfg, Box::new(model)), "127.0.0.1:0").unwrap()
    }

    #[test]
    fn client_completes_against_server() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let r = complete(&addr, "hello", 8, 0.0).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.get("tokens").and_then(|t| t.as_usize()), Some(8));
        h.shutdown();
    }

    #[test]
    fn closed_loop_load() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let prompts: Vec<String> = (0..6).map(|i| format!("prompt {i}")).collect();
        let results = closed_loop(&addr, prompts, 6, 0.0, 3);
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.status == 200));
        let m = metrics(&addr).unwrap();
        assert!(m.get("tokens_out").and_then(|t| t.as_usize()).unwrap_or(0) >= 36);
        h.shutdown();
    }

    #[test]
    fn streaming_matches_blocking_for_same_seed() {
        let h = sim_server();
        let blocking = complete(&h.addr.to_string(), "def f(x):", 12, 0.0).unwrap();
        h.shutdown();
        // a fresh server with the identical engine seed must stream the
        // exact same completion, split into incremental deltas
        let h2 = sim_server();
        let streamed =
            complete_streaming(&h2.addr.to_string(), "def f(x):", 12, 0.0).unwrap();
        h2.shutdown();
        assert_eq!(streamed.status, 200);
        assert!(
            streamed.deltas.len() >= 2,
            "expected incremental deltas, got {}",
            streamed.deltas.len()
        );
        assert_eq!(streamed.tokens(), 12);
        assert_eq!(
            streamed.text(),
            blocking
                .body
                .get("text")
                .and_then(|t| t.as_str())
                .unwrap()
        );
        assert_eq!(
            streamed.finale.get("finish_reason").and_then(|f| f.as_str()),
            Some("max_tokens")
        );
        for w in streamed.deltas.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "deltas must arrive in order");
        }
        assert!(streamed.ttft_s > 0.0 && streamed.ttft_s <= streamed.wall_s);
    }

    #[test]
    fn parse_response_extracts_status_and_body() {
        let r = parse_response(
            "HTTP/1.1 200 OK\r\nContent-Length: 9\r\n\r\n{\"a\": 1}",
            0.5,
        )
        .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.get("a").and_then(|x| x.as_usize()), Some(1));
    }
}
