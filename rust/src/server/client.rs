//! Load-generating HTTP client for the completions API (used by the
//! `serve_http` example and the serving benchmarks).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One completed load-test call.
#[derive(Clone, Debug)]
pub struct CallResult {
    pub status: u16,
    pub wall_s: f64,
    pub body: Json,
}

/// Issue one blocking completions call.
pub fn complete(
    addr: &str,
    prompt: &str,
    max_tokens: usize,
    temperature: f64,
) -> Result<CallResult> {
    let body = Json::obj()
        .set("prompt", prompt)
        .set("max_tokens", max_tokens)
        .set("temperature", temperature)
        .to_string();
    let req = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: dsde\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let wall_s = t0.elapsed().as_secs_f64();
    parse_response(&resp, wall_s)
}

/// Fetch the metrics snapshot.
pub fn metrics(addr: &str) -> Result<Json> {
    let req = "GET /v1/metrics HTTP/1.1\r\nHost: dsde\r\nConnection: close\r\n\r\n";
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    Ok(parse_response(&resp, 0.0)?.body)
}

fn parse_response(resp: &str, wall_s: f64) -> Result<CallResult> {
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response: {resp:.60}"))?;
    let body_text = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .unwrap_or("{}");
    let body = Json::parse(body_text).map_err(|e| anyhow!("body parse: {e}"))?;
    Ok(CallResult {
        status,
        wall_s,
        body,
    })
}

/// Closed-loop load: `concurrency` worker threads each issue
/// `calls_per_worker` sequential completions.  Returns all call results.
pub fn closed_loop(
    addr: &str,
    prompts: Vec<String>,
    max_tokens: usize,
    temperature: f64,
    concurrency: usize,
) -> Vec<CallResult> {
    let addr = addr.to_string();
    let chunks: Vec<Vec<String>> = (0..concurrency)
        .map(|w| {
            prompts
                .iter()
                .skip(w)
                .step_by(concurrency)
                .cloned()
                .collect()
        })
        .collect();
    let mut handles = Vec::new();
    for chunk in chunks {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for p in chunk {
                if let Ok(r) = complete(&addr, &p, max_tokens, temperature) {
                    out.push(r);
                }
            }
            out
        }));
    }
    handles
        .into_iter()
        .flat_map(|h| h.join().unwrap_or_default())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, SlPolicyKind};
    use crate::engine::engine::Engine;
    use crate::model::sim_lm::{SimModel, SimPairKind};
    use crate::server::http::serve;
    use crate::sim::regime::DatasetProfile;

    fn sim_server() -> crate::server::http::ServerHandle {
        let cfg = EngineConfig {
            max_batch: 4,
            max_len: 4096,
            policy: SlPolicyKind::Static(4),
            seed: 2,
            ..Default::default()
        };
        let model = SimModel::new(SimPairKind::LlamaLike, DatasetProfile::cnndm(), 2);
        serve(Engine::new(cfg, Box::new(model)), "127.0.0.1:0").unwrap()
    }

    #[test]
    fn client_completes_against_server() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let r = complete(&addr, "hello", 8, 0.0).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.get("tokens").and_then(|t| t.as_usize()), Some(8));
        h.shutdown();
    }

    #[test]
    fn closed_loop_load() {
        let h = sim_server();
        let addr = h.addr.to_string();
        let prompts: Vec<String> = (0..6).map(|i| format!("prompt {i}")).collect();
        let results = closed_loop(&addr, prompts, 6, 0.0, 3);
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.status == 200));
        let m = metrics(&addr).unwrap();
        assert!(m.get("tokens_out").and_then(|t| t.as_usize()).unwrap_or(0) >= 36);
        h.shutdown();
    }

    #[test]
    fn parse_response_extracts_status_and_body() {
        let r = parse_response(
            "HTTP/1.1 200 OK\r\nContent-Length: 9\r\n\r\n{\"a\": 1}",
            0.5,
        )
        .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body.get("a").and_then(|x| x.as_usize()), Some(1));
    }
}
