//! Durable write-ahead journal for serving requests.
//!
//! The journal promotes the `--record` NDJSON trace into a recovery log:
//! every routed request is appended as a `submit` record *before* it is
//! dispatched to a replica (the router's record hook fires inside
//! dispatch, ahead of the engine send), and every terminal outcome is
//! appended as a `complete` marker when the reply is delivered.  Records
//! are flushed and fsync'd in batches of [`Journal::SYNC_EVERY`] so the
//! hot path pays one `fdatasync` per batch rather than per record; the
//! number of records not yet durable is exported as `journal_lag` on
//! `/v1/metrics`.
//!
//! A journal whose process died can be reloaded with [`load`]: any
//! `submit` without a matching `complete` is *unfinished* and is
//! resubmitted by `serve --resume <journal>`.  A partial final line
//! (the classic torn write) is tolerated and reported as `truncated`;
//! corruption *before* the final record is an error — the file is not a
//! journal any more.  `journal verify <path>` prints the same analysis
//! without serving.
//!
//! `submit` records are a superset of the `eval --replay` trace format
//! ([`crate::eval::trace::TraceEntry`]), so a journal can be replayed
//! directly through the eval harness.

use std::collections::{HashMap, HashSet};
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::engine::request::{PriorityClass, Request, SamplingParams};
use crate::log_warn;
use crate::util::fault::ArmedFaults;
use crate::util::json::Json;

use super::router::RecordHook;

struct JournalInner {
    writer: BufWriter<File>,
    /// Records appended since the last successful fsync.
    pending: u64,
}

/// Append-only, fsync-batched write-ahead journal (see module docs).
pub struct Journal {
    inner: Mutex<JournalInner>,
    /// Records not yet durable (mirrors `inner.pending` for lock-free reads).
    lag: AtomicU64,
    epoch: Instant,
    tag: String,
    faults: Mutex<Option<ArmedFaults>>,
}

impl Journal {
    /// Flush + fsync cadence: one `fdatasync` per this many records.
    pub const SYNC_EVERY: u64 = 32;

    /// Create (truncate) a journal at `path`.  `tag` is stamped on every
    /// `submit` record (it feeds the replay workload label).
    pub fn create(path: &str, tag: &str) -> Result<Journal> {
        let file =
            File::create(path).with_context(|| format!("creating journal at {path}"))?;
        Ok(Journal {
            inner: Mutex::new(JournalInner {
                writer: BufWriter::new(file),
                pending: 0,
            }),
            lag: AtomicU64::new(0),
            epoch: Instant::now(),
            tag: tag.to_string(),
            faults: Mutex::new(None),
        })
    }

    /// Attach armed fault injection (the `DropJournalSync` event makes
    /// [`Journal::lag`] grow without bound).
    pub fn set_faults(&self, faults: ArmedFaults) {
        *self.faults.lock().unwrap() = Some(faults);
    }

    /// Records appended but not yet fsync'd — the durability gap a crash
    /// right now would lose.  Exported as `journal_lag`.
    pub fn lag(&self) -> u64 {
        self.lag.load(Ordering::SeqCst)
    }

    fn sync_dropped(&self) -> bool {
        self.faults
            .lock()
            .unwrap()
            .as_ref()
            .map(|f| f.journal_sync_dropped())
            .unwrap_or(false)
    }

    fn append(&self, line: &str) {
        let drop_sync = self.sync_dropped();
        let mut inner = self.inner.lock().unwrap();
        if let Err(e) = writeln!(inner.writer, "{line}") {
            log_warn!("journal append failed: {e}");
            return;
        }
        inner.pending += 1;
        if inner.pending >= Self::SYNC_EVERY && !drop_sync {
            if let Err(e) = inner
                .writer
                .flush()
                .and_then(|_| inner.writer.get_ref().sync_data())
            {
                log_warn!("journal sync failed: {e}");
            } else {
                inner.pending = 0;
            }
        }
        self.lag.store(inner.pending, Ordering::SeqCst);
    }

    /// Append a `submit` record for a routed request (id already
    /// assigned).  Called by the router's record hook before dispatch.
    pub fn record_submit(&self, req: &Request) {
        let mut line = Json::obj()
            .set("type", "submit")
            .set("id", req.id)
            .set("t", self.epoch.elapsed().as_secs_f64())
            .set("prompt_len", req.prompt.len())
            .set("max_tokens", req.params.max_tokens)
            .set("temperature", req.params.temperature)
            .set("tag", self.tag.as_str())
            .set("prompt", req.prompt.clone());
        // tenancy is a strict-superset extension: fields appear only when
        // non-default, so untagged workloads journal byte-identically to
        // builds that predate multi-tenancy
        if !req.tenant.is_empty() {
            line = line.set("tenant", req.tenant.as_str());
        }
        if req.class != PriorityClass::Standard {
            line = line.set("priority", req.class.name());
        }
        if let Some(d) = req.deadline_ms {
            line = line.set("deadline_ms", d);
        }
        self.append(&line.to_string());
    }

    /// Append a `complete` marker for a finished (or cleanly aborted)
    /// request.
    pub fn record_complete(&self, id: u64, reason: &str) {
        let line = Json::obj()
            .set("type", "complete")
            .set("id", id)
            .set("reason", reason)
            .set("t", self.epoch.elapsed().as_secs_f64())
            .to_string();
        self.append(&line);
    }

    /// Force a flush + fsync regardless of batch fill (shutdown path).
    pub fn sync(&self) {
        let mut inner = self.inner.lock().unwrap();
        if inner
            .writer
            .flush()
            .and_then(|_| inner.writer.get_ref().sync_data())
            .is_ok()
        {
            inner.pending = 0;
        }
        self.lag.store(inner.pending, Ordering::SeqCst);
    }

    /// Build the router record hook that journals every routed request.
    pub fn hook(self: &Arc<Self>) -> RecordHook {
        let journal = Arc::clone(self);
        Box::new(move |req: &Request| journal.record_submit(req))
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        self.sync();
    }
}

/// One `submit` record read back from a journal.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitRecord {
    /// Request id assigned by the router that wrote the journal.
    pub id: u64,
    /// Seconds since the journal was created.
    pub t: f64,
    /// Full prompt token ids.
    pub prompt: Vec<u32>,
    /// Requested output budget.
    pub max_tokens: usize,
    /// Sampling temperature.
    pub temperature: f64,
    /// Workload tag stamped at record time.
    pub tag: String,
    /// Tenant attribution (`""` when the record predates tenancy or the
    /// request was unattributed).
    pub tenant: String,
    /// Priority class (`Standard` when absent from the record).
    pub class: PriorityClass,
    /// Latency SLO in ms from arrival, when one was attached.
    pub deadline_ms: Option<u64>,
}

/// The reconstructed state of a journal file (see [`load`]).
#[derive(Clone, Debug, Default)]
pub struct JournalState {
    /// All `submit` records, in file order.
    pub submits: Vec<SubmitRecord>,
    /// Terminal markers: request id → finish reason.
    pub completed: HashMap<u64, String>,
    /// Whether the final line was a torn write (partial record).
    pub truncated: bool,
    /// `complete` markers whose id was already completed.
    pub double_completed: usize,
    /// `complete` markers whose id was never submitted.
    pub orphan_completes: usize,
}

impl JournalState {
    /// Submitted requests with no completion marker, rebuilt as fresh
    /// [`Request`]s (ids are reassigned by the router on resubmission).
    pub fn unfinished(&self) -> Vec<Request> {
        self.submits
            .iter()
            .filter(|s| !self.completed.contains_key(&s.id))
            .map(|s| {
                Request::new(
                    0,
                    s.prompt.clone(),
                    SamplingParams {
                        temperature: s.temperature,
                        max_tokens: s.max_tokens,
                        stop_token: None,
                    },
                )
                .with_tenancy(&s.tenant, s.class, s.deadline_ms)
            })
            .collect()
    }
}

fn parse_submit(j: &Json, line_no: usize) -> Result<SubmitRecord> {
    let field = |k: &str| {
        j.get(k)
            .and_then(Json::as_f64)
            .with_context(|| format!("journal line {line_no}: submit missing {k:?}"))
    };
    let prompt = match j.get("prompt").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|n| n as u32)
                    .with_context(|| format!("journal line {line_no}: bad prompt token"))
            })
            .collect::<Result<Vec<u32>>>()?,
        // tolerate prompt-less records (hand-written journals): synthesize
        // a prompt of the recorded length so replay shapes still hold
        None => vec![65u32; field("prompt_len")? as usize],
    };
    Ok(SubmitRecord {
        id: field("id")? as u64,
        t: field("t")?,
        prompt,
        max_tokens: field("max_tokens")? as usize,
        temperature: field("temperature")?,
        tag: j
            .get("tag")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        tenant: j
            .get("tenant")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        class: j
            .get("priority")
            .and_then(Json::as_str)
            .and_then(PriorityClass::parse)
            .unwrap_or_default(),
        deadline_ms: j
            .get("deadline_ms")
            .and_then(Json::as_f64)
            .map(|d| d as u64),
    })
}

fn parse_line(state: &mut JournalState, seen: &mut HashSet<u64>, j: &Json, line_no: usize) -> Result<()> {
    match j.get("type").and_then(Json::as_str) {
        Some("submit") => {
            let rec = parse_submit(j, line_no)?;
            seen.insert(rec.id);
            state.submits.push(rec);
            Ok(())
        }
        Some("complete") => {
            let id = j
                .get("id")
                .and_then(Json::as_f64)
                .with_context(|| format!("journal line {line_no}: complete missing id"))?
                as u64;
            let reason = j
                .get("reason")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            if !seen.contains(&id) {
                state.orphan_completes += 1;
            }
            if state.completed.insert(id, reason).is_some() {
                state.double_completed += 1;
            }
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!(
            "journal line {line_no}: unknown record type {other:?}"
        )),
        None => Err(anyhow::anyhow!(
            "journal line {line_no}: record has no \"type\""
        )),
    }
}

/// Load a journal and reconstruct its state.  A malformed *final* line is
/// tolerated (torn write on crash) and flagged as
/// [`JournalState::truncated`]; malformed records anywhere else are an
/// error.
pub fn load(path: &str) -> Result<JournalState> {
    let content =
        std::fs::read_to_string(path).with_context(|| format!("reading journal {path}"))?;
    let lines: Vec<&str> = content
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .collect();
    let mut state = JournalState::default();
    let mut seen = HashSet::new();
    for (i, line) in lines.iter().enumerate() {
        let last = i + 1 == lines.len();
        let parsed = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("journal line {}: {e}", i + 1))
            .and_then(|j| parse_line(&mut state, &mut seen, &j, i + 1));
        if let Err(e) = parsed {
            if last {
                state.truncated = true;
                break;
            }
            return Err(e);
        }
    }
    Ok(state)
}

/// Integrity-check a journal and render a human-readable report
/// (`journal verify <path>`).  Errors if the journal is corrupt before
/// its final record.
pub fn verify(path: &str) -> Result<String> {
    let state = load(path)?;
    let unfinished = state.unfinished();
    let mut out = String::new();
    out.push_str(&format!("journal: {path}\n"));
    out.push_str(&format!("  submitted:        {}\n", state.submits.len()));
    out.push_str(&format!("  completed:        {}\n", state.completed.len()));
    out.push_str(&format!("  unfinished:       {}\n", unfinished.len()));
    out.push_str(&format!(
        "  truncated tail:   {}\n",
        if state.truncated { "yes (torn final record)" } else { "no" }
    ));
    out.push_str(&format!("  double-completed: {}\n", state.double_completed));
    out.push_str(&format!("  orphan completes: {}\n", state.orphan_completes));
    if !unfinished.is_empty() {
        let ids: Vec<String> = state
            .submits
            .iter()
            .filter(|s| !state.completed.contains_key(&s.id))
            .map(|s| s.id.to_string())
            .collect();
        out.push_str(&format!("  unfinished ids:   {}\n", ids.join(", ")));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::env::temp_dir;
    use std::process;

    fn tmp(name: &str) -> String {
        temp_dir()
            .join(format!("dsde-journal-{name}-{}", process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn req(id: u64, prompt_len: usize, max_tokens: usize) -> Request {
        Request::new(
            id,
            vec![65; prompt_len],
            SamplingParams {
                max_tokens,
                ..SamplingParams::default()
            },
        )
    }

    #[test]
    fn roundtrip_tracks_unfinished() {
        let path = tmp("roundtrip");
        let journal = Journal::create(&path, "test").unwrap();
        for i in 1..=6u64 {
            journal.record_submit(&req(i, 8, 16));
        }
        for i in 1..=3u64 {
            journal.record_complete(i, "max_tokens");
        }
        journal.sync();
        let state = load(&path).unwrap();
        assert_eq!(state.submits.len(), 6);
        assert_eq!(state.completed.len(), 3);
        assert!(!state.truncated);
        assert_eq!(state.double_completed, 0);
        assert_eq!(state.orphan_completes, 0);
        let unfinished = state.unfinished();
        assert_eq!(unfinished.len(), 3);
        for r in &unfinished {
            assert_eq!(r.prompt.len(), 8);
            assert_eq!(r.params.max_tokens, 16);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tenancy_rides_the_journal_roundtrip() {
        let path = tmp("tenancy");
        let journal = Journal::create(&path, "test").unwrap();
        journal.record_submit(
            &req(1, 4, 8).with_tenancy("acme", PriorityClass::Interactive, Some(500)),
        );
        journal.record_submit(&req(2, 4, 8)); // untagged: no tenancy keys
        journal.sync();
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert!(lines[0].contains("\"tenant\":\"acme\""), "{}", lines[0]);
        assert!(lines[0].contains("\"priority\":\"interactive\""), "{}", lines[0]);
        assert!(lines[0].contains("\"deadline_ms\":500"), "{}", lines[0]);
        // strict superset: untagged submits carry none of the new keys
        assert!(!lines[1].contains("tenant"), "{}", lines[1]);
        assert!(!lines[1].contains("priority"), "{}", lines[1]);
        assert!(!lines[1].contains("deadline_ms"), "{}", lines[1]);
        let state = load(&path).unwrap();
        let unfinished = state.unfinished();
        assert_eq!(unfinished[0].tenant, "acme");
        assert_eq!(unfinished[0].class, PriorityClass::Interactive);
        assert_eq!(unfinished[0].deadline_ms, Some(500));
        assert_eq!(unfinished[1].tenant, "");
        assert_eq!(unfinished[1].class, PriorityClass::Standard);
        assert_eq!(unfinished[1].deadline_ms, None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_record_is_tolerated() {
        let path = tmp("torn");
        {
            let journal = Journal::create(&path, "test").unwrap();
            journal.record_submit(&req(1, 4, 8));
            journal.record_complete(1, "max_tokens");
            journal.record_submit(&req(2, 4, 8));
            journal.sync();
        }
        // simulate a crash mid-append: a partial record at the tail
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"type\":\"complete\",\"id\":2,").unwrap();
        }
        let state = load(&path).unwrap();
        assert!(state.truncated);
        assert_eq!(state.submits.len(), 2);
        assert_eq!(state.completed.len(), 1);
        assert_eq!(state.unfinished().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_file_corruption_is_an_error() {
        let path = tmp("corrupt");
        std::fs::write(
            &path,
            "this is not json\n{\"type\":\"complete\",\"id\":1,\"reason\":\"max_tokens\",\"t\":0}\n",
        )
        .unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn anomalies_are_counted() {
        let path = tmp("anomaly");
        std::fs::write(
            &path,
            concat!(
                "{\"type\":\"submit\",\"id\":1,\"t\":0,\"prompt_len\":2,\"max_tokens\":4,\"temperature\":0,\"tag\":\"x\"}\n",
                "{\"type\":\"complete\",\"id\":1,\"reason\":\"max_tokens\",\"t\":1}\n",
                "{\"type\":\"complete\",\"id\":1,\"reason\":\"max_tokens\",\"t\":2}\n",
                "{\"type\":\"complete\",\"id\":9,\"reason\":\"aborted\",\"t\":3}\n",
            ),
        )
        .unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.double_completed, 1);
        assert_eq!(state.orphan_completes, 1);
        // prompt-less submit synthesizes from prompt_len
        assert_eq!(state.submits[0].prompt, vec![65, 65]);
        let report = verify(&path).unwrap();
        assert!(report.contains("double-completed: 1"), "{report}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn verify_reports_unfinished_ids() {
        let path = tmp("verify");
        let journal = Journal::create(&path, "test").unwrap();
        journal.record_submit(&req(7, 4, 8));
        journal.sync();
        let report = verify(&path).unwrap();
        assert!(report.contains("unfinished:       1"), "{report}");
        assert!(report.contains("unfinished ids:   7"), "{report}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lag_tracks_unsynced_records() {
        let path = tmp("lag");
        let journal = Journal::create(&path, "test").unwrap();
        assert_eq!(journal.lag(), 0);
        journal.record_submit(&req(1, 4, 8));
        assert_eq!(journal.lag(), 1);
        journal.sync();
        assert_eq!(journal.lag(), 0);
        // a full batch triggers the automatic sync
        for i in 0..Journal::SYNC_EVERY {
            journal.record_submit(&req(i + 2, 4, 8));
        }
        assert_eq!(journal.lag(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sync_drop_fault_grows_lag() {
        use crate::util::fault::FaultPlan;
        let path = tmp("dropsync");
        let journal = Journal::create(&path, "test").unwrap();
        journal.set_faults(FaultPlan::parse("drop-sync@0", 1).unwrap().arm());
        for i in 0..Journal::SYNC_EVERY + 5 {
            journal.record_submit(&req(i + 1, 4, 8));
        }
        assert_eq!(journal.lag(), Journal::SYNC_EVERY + 5);
        let _ = std::fs::remove_file(&path);
    }
}
